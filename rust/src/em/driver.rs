//! The batch EM loop: E-step through a [`Session`], closed-form M-step.
//!
//! [`EmDriver`] mirrors [`crate::nonlinear::IteratedRelinearization`]:
//! a fixed-shape inference problem is re-run each round with only its
//! *data* changed (observation covariances, process-noise messages,
//! scaled state matrices — never the graph structure), so on program
//! engines **every round after the first is a session program-cache
//! hit**. The driver owns convergence (relative parameter movement),
//! divergence detection, and the per-round instrumentation the tests
//! pin: parameter trajectories, cache flags, and the dense
//! log-likelihood (which exact EM must never decrease).
//!
//! The estimand ([`EmEstimand`]) is the glue an application implements:
//! run inference at the current parameter values through the session,
//! extract the posterior marginals, and feed them to its
//! [`super::EmParameter`]s — see [`crate::apps::rls::NoiseEmRls`] and
//! [`crate::apps::kalman::AdaptiveKalman`].

use anyhow::{bail, Result};

use crate::engine::Session;

use super::param::SuffStats;

/// Driver configuration (mirrors [`crate::nonlinear::RelinOptions`]).
#[derive(Clone, Copy, Debug)]
pub struct EmOptions {
    /// Maximum EM rounds.
    pub max_rounds: usize,
    /// Relative parameter movement below which the fixed point is
    /// declared reached.
    pub tol: f64,
    /// Scale-relative movement above which the iteration is declared
    /// divergent. The movement metric is bounded by 2 (it normalizes
    /// by the larger of the old/new magnitudes), so only thresholds
    /// below 2 ever fire — set e.g. `1.5` to stop on violent sign
    /// oscillation. Non-finite parameter values always stop the loop
    /// as [`EmStop::Diverged`], regardless of this threshold.
    pub divergence: f64,
}

impl Default for EmOptions {
    fn default() -> Self {
        EmOptions { max_rounds: 32, tol: 1e-6, divergence: f64::INFINITY }
    }
}

/// Why the driver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmStop {
    /// Parameter movement fell below [`EmOptions::tol`].
    Converged,
    /// [`EmOptions::max_rounds`] rounds ran without convergence.
    MaxRounds,
    /// Movement exceeded [`EmOptions::divergence`] or became non-finite.
    Diverged,
}

/// Result of an EM parameter-estimation run.
#[derive(Clone, Debug)]
pub struct EmReport {
    /// Final parameter values, in the estimand's order.
    pub values: Vec<f64>,
    /// Rounds executed.
    pub rounds: usize,
    /// Why the driver stopped.
    pub stop: EmStop,
    /// Parameter values after each round's M-step.
    pub history: Vec<Vec<f64>>,
    /// Dense log-likelihood at the values *entering* each round, plus
    /// one final entry at the converged values — non-decreasing for
    /// exact EM (pinned by `rust/tests/property_em.rs`). Empty when the
    /// estimand has no tractable reference.
    pub log_likelihood: Vec<f64>,
    /// Per-round program-cache flags (true = every compiled program the
    /// round needed came from the session cache). Always false on
    /// engines without programs.
    pub cached: Vec<bool>,
}

impl EmReport {
    /// True when the driver reached the movement tolerance.
    pub fn converged(&self) -> bool {
        self.stop == EmStop::Converged
    }
}

/// An estimation problem with unknown parameters, as the driver sees it.
///
/// The contract:
///
/// 1. [`values`](EmEstimand::values) reports the current parameter
///    values in a fixed order (the driver tracks movement over them);
/// 2. [`e_step`](EmEstimand::e_step) runs inference **at the current
///    values** through the session — batch [`Session::run`]/
///    [`Session::dispatch`], a [`Session::run_stream`] pass, or a GBP
///    solve — and folds each section's posterior marginals into the
///    per-parameter accumulators. Only data may change between rounds;
///    the model *shape* must stay fixed so rounds hit the program
///    cache. Returns true when every program the round needed came from
///    the cache;
/// 3. [`m_step`](EmEstimand::m_step) commits the closed-form updates
///    and returns the new values.
pub trait EmEstimand {
    /// Current parameter values, in a fixed order.
    fn values(&self) -> Vec<f64>;

    /// One E-step at the current values (see the trait docs).
    fn e_step(&mut self, session: &mut Session, acc: &mut [SuffStats]) -> Result<bool>;

    /// Commit the closed-form M-steps; returns the new values.
    fn m_step(&mut self, acc: &[SuffStats]) -> Result<Vec<f64>>;

    /// Dense log-likelihood at the current values, when the model has a
    /// tractable reference (monotone-ascent instrumentation).
    fn log_likelihood(&self) -> Result<Option<f64>> {
        Ok(None)
    }
}

/// The EM loop: E-step → closed-form M-step → movement check.
#[derive(Clone, Copy, Debug, Default)]
pub struct EmDriver {
    /// Convergence configuration.
    pub opts: EmOptions,
}

impl EmDriver {
    /// Driver with default options.
    pub fn new() -> Self {
        EmDriver { opts: EmOptions::default() }
    }

    /// Driver with explicit options.
    pub fn with_options(opts: EmOptions) -> Self {
        EmDriver { opts }
    }

    /// Run EM to the fixed point through a [`Session`] (any engine).
    pub fn run(&self, session: &mut Session, est: &mut dyn EmEstimand) -> Result<EmReport> {
        if self.opts.max_rounds == 0 {
            bail!("max_rounds must be at least 1");
        }
        let mut values = est.values();
        if values.is_empty() {
            bail!("estimand declares no parameters");
        }
        let mut history = Vec::new();
        let mut log_likelihood = Vec::new();
        let mut cached = Vec::new();
        let mut stop = EmStop::MaxRounds;
        for _ in 0..self.opts.max_rounds {
            if let Some(ll) = est.log_likelihood()? {
                log_likelihood.push(ll);
            }
            let mut acc = vec![SuffStats::default(); values.len()];
            cached.push(est.e_step(session, &mut acc)?);
            let new = est.m_step(&acc)?;
            if new.len() != values.len() {
                bail!(
                    "M-step returned {} values for {} parameters",
                    new.len(),
                    values.len()
                );
            }
            let delta = movement(&values, &new);
            history.push(new.clone());
            values = new;
            if values.iter().any(|v| !v.is_finite())
                || delta.is_nan()
                || delta > self.opts.divergence
            {
                stop = EmStop::Diverged;
                break;
            }
            if delta < self.opts.tol {
                stop = EmStop::Converged;
                break;
            }
        }
        // final log-likelihood at the converged values
        if let Some(ll) = est.log_likelihood()? {
            log_likelihood.push(ll);
        }
        Ok(EmReport {
            values,
            rounds: history.len(),
            stop,
            history,
            log_likelihood,
            cached,
        })
    }
}

/// Max per-parameter movement, relative to the parameter's own scale:
/// variances can sit orders of magnitude below 1, so normalizing by
/// `max(1, |θ|)` would declare convergence on what is still a large
/// relative step. A NaN delta (non-finite parameters) propagates
/// instead of being dropped by the max-fold, so the driver sees it.
fn movement(old: &[f64], new: &[f64]) -> f64 {
    let mut worst = 0.0_f64;
    for (o, n) in old.iter().zip(new) {
        let scale = o.abs().max(n.abs());
        let d = if scale == 0.0 { 0.0 } else { (o - n).abs() / scale };
        if d.is_nan() {
            return f64::NAN;
        }
        worst = worst.max(d);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::param::{EmParameter, Evidence, ScalarCoeff};
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::{assert_close, Rng};

    /// A host-side AR(1) estimand: x_t = θ x_{t-1} + w observed as
    /// y_t = x_t + v, the E-step running the exact filtered pair
    /// recursion in f64 (no engine — the driver only needs the session
    /// for engine-backed estimands).
    struct ArEstimand {
        ys: Vec<Vec<c64>>,
        q: f64,
        r: f64,
        n: usize,
        theta: ScalarCoeff,
    }

    impl ArEstimand {
        fn synthetic(steps: usize, theta: f64, q: f64, r: f64, seed: u64) -> Self {
            let n = 4;
            let mut rng = Rng::new(seed);
            let mut x: Vec<f64> = (0..n).map(|_| rng.range(-0.5, 0.5)).collect();
            let mut ys = Vec::with_capacity(steps);
            for _ in 0..steps {
                for xi in x.iter_mut() {
                    *xi = theta * *xi + rng.normal() * q.sqrt();
                }
                ys.push(
                    x.iter()
                        .map(|xi| c64::new(xi + rng.normal() * r.sqrt(), 0.0))
                        .collect(),
                );
            }
            ArEstimand { ys, q, r, n, theta: ScalarCoeff::new(0.3) }
        }
    }

    impl EmEstimand for ArEstimand {
        fn values(&self) -> Vec<f64> {
            vec![self.theta.value()]
        }

        fn e_step(&mut self, _session: &mut Session, acc: &mut [SuffStats]) -> Result<bool> {
            let n = self.n;
            let th = self.theta.value();
            let mut m = vec![c64::ZERO; n];
            let mut v = CMatrix::scaled_identity(n, 1.0);
            for y in &self.ys {
                // joint of (x_prev, x_cur) before y: x_cur = θ x_prev + w
                let vp = v.scale(th * th).add(&CMatrix::scaled_identity(n, self.q));
                let cross = v.scale(th); // Cov(x_cur, x_prev)
                let s = vp.add(&CMatrix::scaled_identity(n, self.r));
                let sinv = s.inverse().expect("S is PD");
                let nu: Vec<c64> = y.iter().zip(&m).map(|(yo, mo)| *yo - *mo * th).collect();
                let m_cur: Vec<c64> = {
                    let g = vp.matmul(&sinv);
                    let corr = g.matvec(&nu);
                    m.iter().zip(&corr).map(|(mo, c)| *mo * th + *c).collect()
                };
                let v_cur = vp.sub(&vp.matmul(&sinv).matmul(&vp));
                let m_prev: Vec<c64> = {
                    let g = cross.hermitian().matmul(&sinv);
                    let corr = g.matvec(&nu);
                    m.iter().zip(&corr).map(|(mo, c)| *mo + *c).collect()
                };
                let v_prev = v.sub(&cross.hermitian().matmul(&sinv).matmul(&cross));
                let cov_cur_prev = cross.sub(&vp.matmul(&sinv).matmul(&cross));
                self.theta.accumulate(
                    &Evidence::Pair {
                        cur_mean: &m_cur,
                        prev_mean: &m_prev,
                        cross_cov: &cov_cur_prev,
                        prev_cov: &v_prev,
                    },
                    &mut acc[0],
                )?;
                m = m_cur;
                v = v_cur;
            }
            Ok(false)
        }

        fn m_step(&mut self, acc: &[SuffStats]) -> Result<Vec<f64>> {
            Ok(vec![self.theta.m_step(&acc[0])?])
        }
    }

    #[test]
    fn ar_coefficient_converges_near_truth() {
        let mut est = ArEstimand::synthetic(300, 0.9, 0.05, 0.02, 4);
        let driver = EmDriver::with_options(EmOptions {
            max_rounds: 60,
            tol: 1e-8,
            divergence: 1e6,
        });
        let report = driver.run(&mut Session::golden(), &mut est).unwrap();
        assert!(report.converged(), "stop {:?}", report.stop);
        let theta = report.values[0];
        assert!(
            (theta - 0.9).abs() < 0.05,
            "theta {theta} strayed from 0.9 (rounds {})",
            report.rounds
        );
        // trajectory moved from the 0.3 start monotonically toward truth
        assert!(report.history[0][0] > 0.3);
        assert_close(*report.history.last().unwrap().first().unwrap(), theta, 1e-12);
    }

    #[test]
    fn zero_rounds_is_an_error() {
        let mut est = ArEstimand::synthetic(4, 0.5, 0.05, 0.02, 1);
        let driver = EmDriver::with_options(EmOptions { max_rounds: 0, ..Default::default() });
        assert!(driver.run(&mut Session::golden(), &mut est).is_err());
    }

    #[test]
    fn max_rounds_is_reported_not_spun() {
        let mut est = ArEstimand::synthetic(50, 0.8, 0.05, 0.02, 2);
        let driver = EmDriver::with_options(EmOptions {
            max_rounds: 2,
            tol: 0.0,
            divergence: 1e6,
        });
        let report = driver.run(&mut Session::golden(), &mut est).unwrap();
        assert_eq!(report.stop, EmStop::MaxRounds);
        assert_eq!(report.rounds, 2);
        assert_eq!(report.history.len(), 2);
    }
}
