//! Online / recursive EM riding the streaming surface unchanged.
//!
//! Batch EM re-runs the whole chain per round; a serving receiver never
//! gets that luxury — samples arrive once. Recursive EM (Dauwels et
//! al., part I, §"online EM") folds each new posterior marginal into
//! exponentially discounted sufficient statistics and commits the
//! closed-form M-step as it streams.
//!
//! [`OnlineEm`] wraps any [`OnlineNoiseSource`] (a streaming workload
//! whose observation noise can be re-estimated mid-stream) and is
//! itself a [`StreamingWorkload`]: `Session::run_stream` and the
//! coordinator's sticky farm streams ([`crate::coordinator::FgpFarm::
//! open_stream`]) drive it **unchanged**. The driver hands the wrapper
//! the latest recursive state at every chunk boundary; the wrapper
//! detects the boundary, absorbs the samples that state now
//! incorporates into the discounted [`SuffStats`], re-commits the
//! [`ObsNoiseVar`] M-step, and emits the next samples with observation
//! messages rebuilt at the fresh estimate. Chunked engines simply
//! accumulate per chunk instead of per sample — the contract the
//! tentpole tests pin on golden, fgp-sim and the farm.

use std::cell::RefCell;

use anyhow::Result;

use crate::compiler::CompileOptions;
use crate::engine::{StreamRun, StreamSample, StreamingWorkload};
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, Schedule};

use super::param::{EmParameter, Evidence, ObsNoiseVar, SuffStats};

/// Default per-sample exponential forgetting factor λ: statistics decay
/// with a ~200-sample memory, so estimates computed under an early,
/// badly wrong σ̂² wash out instead of biasing the average forever.
pub const DEFAULT_FORGET: f64 = 0.995;

/// Default number of samples absorbed before the first M-step commits
/// (a variance estimate from a handful of residuals is noise).
pub const DEFAULT_BURN_IN: usize = 8;

/// One observation section's data, as online EM needs it: the map, the
/// observed vector, and which components carry real observations.
#[derive(Clone, Debug)]
pub struct OnlineSection {
    /// Observation map / regressor matrix of the sample.
    pub a: CMatrix,
    /// Observed data vector (mean of the observation message).
    pub y: Vec<c64>,
    /// Components of `y` carrying real observations.
    pub observed: Vec<usize>,
}

/// A recursive workload whose observation-noise variance can be
/// re-estimated while it streams.
///
/// Implementors keep their [`StreamingWorkload`] contract untouched;
/// the two extra methods let [`OnlineEm`] rebuild each sample's
/// observation message at the current noise estimate and extract the
/// section's E-step evidence.
pub trait OnlineNoiseSource: StreamingWorkload {
    /// Sample `k` with its observation message rebuilt at noise
    /// variance `sigma2` (`None` at end of stream).
    fn sample_at(&self, k: usize, sigma2: f64) -> Result<Option<StreamSample>>;

    /// Section data of sample `k` for the E-step accumulator (`None`
    /// past the end of the stream).
    fn section(&self, k: usize) -> Option<OnlineSection>;
}

/// Outcome of an online-EM stream: the wrapped workload's outcome plus
/// the final noise estimate.
#[derive(Clone, Debug)]
pub struct OnlineEmOutcome<O> {
    /// The wrapped workload's stream outcome.
    pub inner: O,
    /// Final observation-noise variance estimate.
    pub sigma2: f64,
}

struct OnlineState {
    noise: ObsNoiseVar,
    acc: SuffStats,
    /// Samples already absorbed into the statistics.
    seen: usize,
    /// Last recursive state observed from the driver (chunk-boundary
    /// detection: the state only changes when a dispatch lands).
    last: Option<GaussMessage>,
    /// Chunk size learned from the first state change (the sample index
    /// at the first boundary IS the driver's chunk). Once known, every
    /// `k % chunk == 0` call is a boundary even if the posterior has
    /// reached a bitwise fixed point (quantized engines can freeze the
    /// state exactly; adaptation must not stall on that).
    chunk: Option<usize>,
}

/// Online/recursive EM over a streaming workload (see the module docs).
pub struct OnlineEm<W> {
    inner: W,
    name: String,
    /// Per-sample exponential forgetting factor λ ∈ (0, 1].
    pub forget: f64,
    /// Samples absorbed before the first M-step commits.
    pub burn_in: usize,
    st: RefCell<OnlineState>,
}

impl<W: OnlineNoiseSource> OnlineEm<W> {
    /// Wrap `inner`, starting the noise estimate at `sigma0`.
    pub fn new(inner: W, sigma0: f64) -> Self {
        let name = format!("{}+em", inner.stream_name());
        OnlineEm {
            inner,
            name,
            forget: DEFAULT_FORGET,
            burn_in: DEFAULT_BURN_IN,
            st: RefCell::new(OnlineState {
                noise: ObsNoiseVar::new(sigma0),
                acc: SuffStats::default(),
                seen: 0,
                last: None,
                chunk: None,
            }),
        }
    }

    /// Override the forgetting factor (λ = 1 is a plain running mean).
    pub fn with_forget(mut self, forget: f64) -> Self {
        self.forget = forget;
        self
    }

    /// The wrapped workload.
    pub fn inner(&self) -> &W {
        &self.inner
    }

    /// Current observation-noise estimate.
    pub fn estimate(&self) -> f64 {
        self.st.borrow().noise.value()
    }

    /// Absorb samples `[seen, upto)` using `marginal` (the recursive
    /// state that now incorporates them), then re-commit the M-step.
    fn absorb(&self, upto: usize, marginal: &GaussMessage) -> Result<()> {
        let mut st = self.st.borrow_mut();
        let st = &mut *st;
        for k in st.seen..upto {
            let Some(sec) = self.inner.section(k) else { continue };
            st.acc.discount(self.forget);
            st.noise.accumulate(
                &Evidence::Observation {
                    marginal,
                    a: &sec.a,
                    y: &sec.y,
                    observed: &sec.observed,
                },
                &mut st.acc,
            )?;
        }
        st.seen = st.seen.max(upto);
        if st.seen >= self.burn_in && st.acc.den > 0.0 {
            st.noise.m_step(&st.acc)?;
        }
        Ok(())
    }
}

impl<W: OnlineNoiseSource> StreamingWorkload for OnlineEm<W> {
    type StreamOutcome = OnlineEmOutcome<W::StreamOutcome>;

    fn stream_name(&self) -> &str {
        &self.name
    }

    fn state_dim(&self) -> usize {
        self.inner.state_dim()
    }

    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
        self.inner.stream_model(chunk)
    }

    fn state_label(&self) -> &str {
        self.inner.state_label()
    }

    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        self.inner.constant_inputs()
    }

    fn initial_state(&self) -> GaussMessage {
        self.inner.initial_state()
    }

    fn next_sample(&self, k: usize, state: &GaussMessage) -> Result<Option<StreamSample>> {
        let boundary = {
            let mut st = self.st.borrow_mut();
            let changed = match &st.last {
                None => true,
                Some(prev) => prev.dist(state) != 0.0,
            };
            if changed && st.chunk.is_none() && k > 0 {
                // the first state change happens at the first call of
                // the second chunk, where k == the driver's chunk size
                st.chunk = Some(k);
            }
            // a known chunk also identifies boundaries when the
            // posterior is at a bitwise fixed point (state unchanged)
            changed || st.chunk.map_or(false, |c| k % c == 0 && k > st.seen)
        };
        if boundary {
            // the driver hands the post-dispatch state at the first call
            // of each chunk, where k == samples consumed so far: every
            // sample in [seen, k) is now inside `state`
            self.absorb(k, state)?;
            self.st.borrow_mut().last = Some(state.clone());
        }
        let sigma2 = self.estimate();
        self.inner.sample_at(k, sigma2)
    }

    fn max_chunk(&self) -> usize {
        self.inner.max_chunk()
    }

    fn stream_compile_options(&self) -> CompileOptions {
        self.inner.stream_compile_options()
    }

    fn stream_outcome(&self, run: &StreamRun) -> Result<Self::StreamOutcome> {
        // the final state incorporates the whole stream: absorb the tail
        self.absorb(run.samples as usize, &run.final_state)?;
        let inner = self.inner.stream_outcome(run)?;
        Ok(OnlineEmOutcome { inner, sigma2: self.estimate() })
    }
}
