//! Dense log-likelihood reference for EM monotone-ascent pinning.
//!
//! Exact EM never decreases the data log-likelihood. For the static-
//! state observation chains the RLS fixture uses (one state, many
//! sections `y_i = A_i x + v_i`), the likelihood factorizes through the
//! chain rule of sequential conditioning:
//! `log p(y_{1:S} | σ²) = Σ_i log N(y_i ; A_i m_{i-1}, A_i V_{i-1} A_iᴴ + σ²)`
//! where `(m_{i-1}, V_{i-1})` is the posterior given the previous
//! sections. Each observed component conditions the running state by a
//! rank-1 update, so the whole reference is a small f64 sweep —
//! feasible at test sizes, which is all a reference must be.

use anyhow::{bail, Result};

use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;

/// One observation section of the dense reference: `y = A x + v` with
/// `v ~ CN(0, σ² I)` on the listed components.
#[derive(Clone, Copy, Debug)]
pub struct NoiseSection<'a> {
    /// Observation map / regressor matrix `A`.
    pub a: &'a CMatrix,
    /// Observed data vector.
    pub y: &'a [c64],
    /// Components of `y` carrying real observations (zero rows of `A`
    /// are padding and contribute no likelihood).
    pub observed: &'a [usize],
}

/// Dense log-likelihood `log p(y_{1:S} | σ²)` of an observation chain
/// under the circular complex-Gaussian noise model, by sequential
/// scalar conditioning. Errors if a predictive variance is not
/// positive (a singular model).
pub fn chain_log_likelihood<'a>(
    prior: &GaussMessage,
    sections: impl IntoIterator<Item = NoiseSection<'a>>,
    sigma2: f64,
) -> Result<f64> {
    if sigma2 <= 0.0 {
        bail!("noise variance must be positive, got {sigma2}");
    }
    let n = prior.dim();
    let mut m = prior.mean.clone();
    let mut v = prior.cov.clone();
    let mut ll = 0.0;
    for (si, sec) in sections.into_iter().enumerate() {
        if sec.a.cols != n {
            bail!("section {si}: A has {} cols but the state is n={n}", sec.a.cols);
        }
        for &o in sec.observed {
            if o >= sec.a.rows || o >= sec.y.len() {
                bail!("section {si}: observed component {o} out of range");
            }
            // row o of A as a 1 x n matrix
            let mut row = CMatrix::zeros(1, n);
            for j in 0..n {
                row[(0, j)] = sec.a[(o, j)];
            }
            let vrh = v.matmul(&row.hermitian()); // V aᴴ, n x 1
            let s = row.matmul(&vrh)[(0, 0)].re + sigma2;
            if s <= 0.0 {
                bail!("section {si}: non-positive predictive variance {s}");
            }
            let pred: c64 = (0..n).map(|j| row[(0, j)] * m[j]).fold(c64::ZERO, |a, b| a + b);
            let r = sec.y[o] - pred;
            ll += -(std::f64::consts::PI * s).ln() - r.abs2() / s;
            // rank-1 condition: m += V aᴴ r / s, V -= (V aᴴ)(a V) / s
            for (mi, k) in m.iter_mut().zip(0..n) {
                *mi = *mi + vrh[(k, 0)] * r * (1.0 / s);
            }
            let av = row.matmul(&v); // a V, 1 x n
            v = v.sub(&vrh.matmul(&av).scale(1.0 / s));
        }
    }
    Ok(ll)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    /// Scalar sanity: one state, one observation through identity.
    /// log p(y) = log N(y; m0, V0 + sigma2) in the circular convention.
    #[test]
    fn single_scalar_section_matches_closed_form() {
        let prior = GaussMessage::new(vec![c64::new(0.5, 0.0)], CMatrix::scaled_identity(1, 0.3));
        let a = CMatrix::identity(1);
        let y = [c64::new(1.0, 0.0)];
        let observed = [0usize];
        let ll = chain_log_likelihood(
            &prior,
            [NoiseSection { a: &a, y: &y, observed: &observed }],
            0.2,
        )
        .unwrap();
        let s = 0.3 + 0.2;
        let want = -(std::f64::consts::PI * s).ln() - 0.25 / s;
        assert_close(ll, want, 1e-12);
    }

    /// Chain rule: conditioning order must not change the total.
    #[test]
    fn two_sections_factorize() {
        let mut rng = crate::testutil::Rng::new(3);
        let n = 3;
        let prior = GaussMessage::isotropic(n, 1.0);
        let a1 = CMatrix::random(&mut rng, n, n);
        let a2 = CMatrix::random(&mut rng, n, n);
        let y1: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let y2: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let obs = [0usize];
        let both = chain_log_likelihood(
            &prior,
            [
                NoiseSection { a: &a1, y: &y1, observed: &obs },
                NoiseSection { a: &a2, y: &y2, observed: &obs },
            ],
            0.1,
        )
        .unwrap();
        // p(y1, y2) = p(y1) p(y2 | y1): recompute p(y1) alone and check
        // the difference equals the conditional term by re-running with
        // the sections swapped (joint likelihood is order-invariant)
        let swapped = chain_log_likelihood(
            &prior,
            [
                NoiseSection { a: &a2, y: &y2, observed: &obs },
                NoiseSection { a: &a1, y: &y1, observed: &obs },
            ],
            0.1,
        )
        .unwrap();
        assert_close(both, swapped, 1e-9);
    }

    #[test]
    fn likelihood_peaks_near_true_noise() {
        // data drawn at sigma2 = 0.05 scores higher there than at 10x/0.1x
        let mut rng = crate::testutil::Rng::new(9);
        let n = 4;
        let sections = 64;
        let sigma2 = 0.05;
        let x: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let mut mats = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..sections {
            let a = CMatrix::random(&mut rng, n, n);
            let am = a.matvec(&x);
            let noise = c64::new(
                rng.normal() * (sigma2 / 2.0).sqrt(),
                rng.normal() * (sigma2 / 2.0).sqrt(),
            );
            ys.push(vec![am[0] + noise]);
            mats.push(a);
        }
        let obs = [0usize];
        let ll_at = |s2: f64| {
            chain_log_likelihood(
                &GaussMessage::isotropic(n, 4.0),
                mats.iter()
                    .zip(&ys)
                    .map(|(a, y)| NoiseSection { a, y, observed: &obs }),
                s2,
            )
            .unwrap()
        };
        assert!(ll_at(sigma2) > ll_at(sigma2 * 10.0));
        assert!(ll_at(sigma2) > ll_at(sigma2 * 0.1));
    }

    #[test]
    fn bad_inputs_error_not_panic() {
        let prior = GaussMessage::isotropic(2, 1.0);
        let a = CMatrix::identity(2);
        let y = [c64::ZERO; 2];
        let obs_oob = [5usize];
        assert!(chain_log_likelihood(
            &prior,
            [NoiseSection { a: &a, y: &y, observed: &obs_oob }],
            0.1
        )
        .is_err());
        let obs = [0usize];
        assert!(chain_log_likelihood(
            &prior,
            [NoiseSection { a: &a, y: &y, observed: &obs }],
            0.0
        )
        .is_err());
    }
}
