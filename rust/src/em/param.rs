//! EM parameters: node-local expectations + closed-form M-steps.
//!
//! Dauwels et al. tabulate the EM message a factor node sends to an
//! unknown parameter: an expected sufficient statistic of the node's
//! *local* variables under the current posterior. Every closed-form
//! Gaussian M-step in that table is the **ratio of two accumulated
//! expectations** — a residual power over a count for noise variances,
//! a cross-moment over a second moment for linear coefficients. This
//! module reifies exactly that structure:
//!
//! * [`SuffStats`] — the `(num, den)` accumulator pair, with the
//!   exponential discounting online/recursive EM needs;
//! * [`Evidence`] — the posterior marginals one section contributes to
//!   the E-step (produced by any engine run: a batch `Session::run`, a
//!   `Session::run_stream` boundary, or a GBP belief);
//! * [`EmParameter`] — the trait tying a parameter's E-step accumulation
//!   to its closed-form M-step, with the first three implementations:
//!   [`ObsNoiseVar`], [`ProcessNoiseVar`] and [`ScalarCoeff`].
//!
//! Parameters never run inference and never see an engine: an estimand
//! (e.g. [`crate::apps::rls::NoiseEmRls`]) extracts the marginals from a
//! session run and feeds them here — the node-local update rules stay
//! composable exactly as Cox et al. prescribe.

use anyhow::{bail, Result};

use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;

/// Accumulated expected sufficient statistics of one EM parameter.
///
/// Every closed-form Gaussian M-step served here is `num / den`:
/// expected residual power over a component count for a noise variance,
/// expected cross-moment over a second moment for a linear coefficient.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SuffStats {
    /// Numerator accumulator (expected residual power / cross-moment).
    pub num: f64,
    /// Denominator accumulator (component count / second moment).
    pub den: f64,
}

impl SuffStats {
    /// Fold another accumulator in (merging per-chunk partial sums).
    pub fn merge(&mut self, other: &SuffStats) {
        self.num += other.num;
        self.den += other.den;
    }

    /// Exponentially discount the history (online/recursive EM): both
    /// accumulators shrink by `lambda` before the next section lands.
    pub fn discount(&mut self, lambda: f64) {
        self.num *= lambda;
        self.den *= lambda;
    }

    /// The closed-form ratio, or `None` while nothing has accumulated.
    pub fn ratio(&self) -> Option<f64> {
        (self.den > 0.0).then(|| self.num / self.den)
    }
}

/// Posterior evidence one model section contributes to the E-step.
///
/// The variants mirror where the three parameter kinds live in a
/// Gaussian model: at an observation node, at a noise input, or across
/// a transition. The *estimand* builds these from engine-produced
/// marginals; the parameter only takes expectations.
#[derive(Clone, Copy, Debug)]
pub enum Evidence<'a> {
    /// An observation section `y = A x + v`: the posterior marginal of
    /// the observed state plus the section's data.
    Observation {
        /// Posterior marginal of the state `x` the section observes.
        marginal: &'a GaussMessage,
        /// Observation map / regressor matrix `A`.
        a: &'a CMatrix,
        /// Observed data vector (mean of the observation message).
        y: &'a [c64],
        /// Components of `y` that carry real observations (rows of `A`
        /// that are zero padding contribute no residual information and
        /// must be excluded, or the variance estimate biases low).
        observed: &'a [usize],
    },
    /// The posterior marginal of a noise variable itself (e.g. the
    /// process-noise input `w` of one transition, as produced by a
    /// lag-one finalized filter step).
    Noise {
        /// Posterior marginal of the noise variable.
        marginal: &'a GaussMessage,
    },
    /// Joint posterior moments of a transition pair `x_cur = θ x_prev + w`
    /// (scalar coefficient estimation needs the cross term).
    Pair {
        /// Posterior mean of the successor state `x_cur`.
        cur_mean: &'a [c64],
        /// Posterior mean of the predecessor state `x_prev`.
        prev_mean: &'a [c64],
        /// Posterior cross-covariance `Cov(x_cur, x_prev | data)`.
        cross_cov: &'a CMatrix,
        /// Posterior covariance of the predecessor state.
        prev_cov: &'a CMatrix,
    },
}

/// An unknown scalar model parameter estimated by EM.
///
/// [`accumulate`](EmParameter::accumulate) is the E-step contribution of
/// one section (consuming posterior marginals only — Dauwels' "EM as
/// message passing" table); [`m_step`](EmParameter::m_step) commits the
/// closed-form update and returns the new value. Implementations reject
/// evidence variants they have no rule for, so wiring mistakes surface
/// as typed errors instead of silent misestimates.
pub trait EmParameter {
    /// Short identifier (reports, diagnostics).
    fn name(&self) -> &str;

    /// Current parameter value.
    fn value(&self) -> f64;

    /// E-step: fold one section's posterior evidence into `acc`.
    fn accumulate(&self, ev: &Evidence, acc: &mut SuffStats) -> Result<()>;

    /// M-step: commit the closed-form update from `acc`, returning the
    /// new value. Errors if nothing was accumulated.
    fn m_step(&mut self, acc: &SuffStats) -> Result<f64>;
}

// ---------------------------------------------------------------------
// Observation-noise variance
// ---------------------------------------------------------------------

/// Unknown observation-noise variance `σ²` of `y = A x + v`,
/// `v ~ CN(0, σ² I)` on the observed components.
///
/// E-step per observed component `o`:
/// `E|y_o − (A x)_o|² = |y_o − (A m)_o|² + (A V Aᴴ)_oo` under the
/// posterior `x ~ N(m, V)`; M-step: `σ²' = Σ E|r_o|² / #components`
/// (floored to stay a proper variance).
#[derive(Clone, Copy, Debug)]
pub struct ObsNoiseVar {
    sigma2: f64,
    floor: f64,
}

impl ObsNoiseVar {
    /// Start the estimate at `sigma0` (must be positive).
    pub fn new(sigma0: f64) -> Self {
        ObsNoiseVar { sigma2: sigma0.max(1e-12), floor: 1e-9 }
    }

    /// Override the positivity floor the M-step clamps to.
    pub fn with_floor(mut self, floor: f64) -> Self {
        self.floor = floor;
        self
    }
}

impl EmParameter for ObsNoiseVar {
    fn name(&self) -> &str {
        "obs_noise_var"
    }

    fn value(&self) -> f64 {
        self.sigma2
    }

    fn accumulate(&self, ev: &Evidence, acc: &mut SuffStats) -> Result<()> {
        let Evidence::Observation { marginal, a, y, observed } = ev else {
            bail!("obs-noise variance needs Observation evidence");
        };
        let am = a.matvec(&marginal.mean);
        let avah = a.matmul(&marginal.cov).matmul(&a.hermitian());
        for &o in *observed {
            if o >= y.len() || o >= a.rows {
                bail!(
                    "observed component {o} out of range (y dim {}, A rows {})",
                    y.len(),
                    a.rows
                );
            }
            let r = y[o] - am[o];
            acc.num += r.abs2() + avah[(o, o)].re;
            acc.den += 1.0;
        }
        Ok(())
    }

    fn m_step(&mut self, acc: &SuffStats) -> Result<f64> {
        let Some(ratio) = acc.ratio() else {
            bail!("obs-noise M-step with no accumulated sections");
        };
        self.sigma2 = ratio.max(self.floor);
        Ok(self.sigma2)
    }
}

// ---------------------------------------------------------------------
// Process-noise variance
// ---------------------------------------------------------------------

/// Unknown isotropic process-noise variance `q` of `x' = F x + w`,
/// `w ~ N(0, q I)`.
///
/// E-step: the estimand hands over the posterior marginal of the noise
/// variable `w` itself ([`Evidence::Noise`], e.g. from a lag-one
/// finalized filter recursion); the expectation is then node-local:
/// `E‖w‖² = ‖m_w‖² + Re tr V_w`. M-step: `q' = Σ E‖w‖² / Σ dim(w)`.
#[derive(Clone, Copy, Debug)]
pub struct ProcessNoiseVar {
    q: f64,
    floor: f64,
}

impl ProcessNoiseVar {
    /// Start the estimate at `q0` (must be positive).
    pub fn new(q0: f64) -> Self {
        ProcessNoiseVar { q: q0.max(1e-12), floor: 1e-9 }
    }

    /// Override the positivity floor the M-step clamps to.
    pub fn with_floor(mut self, floor: f64) -> Self {
        self.floor = floor;
        self
    }
}

impl EmParameter for ProcessNoiseVar {
    fn name(&self) -> &str {
        "process_noise_var"
    }

    fn value(&self) -> f64 {
        self.q
    }

    fn accumulate(&self, ev: &Evidence, acc: &mut SuffStats) -> Result<()> {
        let Evidence::Noise { marginal } = ev else {
            bail!("process-noise variance needs Noise evidence");
        };
        let power: f64 = marginal.mean.iter().map(|m| m.abs2()).sum();
        acc.num += power + marginal.cov.trace().re;
        acc.den += marginal.dim() as f64;
        Ok(())
    }

    fn m_step(&mut self, acc: &SuffStats) -> Result<f64> {
        let Some(ratio) = acc.ratio() else {
            bail!("process-noise M-step with no accumulated sections");
        };
        self.q = ratio.max(self.floor);
        Ok(self.q)
    }
}

// ---------------------------------------------------------------------
// Scalar AR / channel coefficient
// ---------------------------------------------------------------------

/// Unknown real scalar coefficient `θ` of a transition
/// `x_cur = θ x_prev + w` (an AR(1) memory / fading-channel
/// coefficient).
///
/// E-step from the joint posterior moments of the pair:
/// numerator `Re⟨m_cur, m_prev⟩ + Re tr Cov(x_cur, x_prev)`,
/// denominator `‖m_prev‖² + Re tr V_prev`; M-step `θ' = num / den` —
/// the scalar least-squares projection under the posterior.
#[derive(Clone, Copy, Debug)]
pub struct ScalarCoeff {
    theta: f64,
}

impl ScalarCoeff {
    /// Start the estimate at `theta0`.
    pub fn new(theta0: f64) -> Self {
        ScalarCoeff { theta: theta0 }
    }
}

impl EmParameter for ScalarCoeff {
    fn name(&self) -> &str {
        "scalar_coeff"
    }

    fn value(&self) -> f64 {
        self.theta
    }

    fn accumulate(&self, ev: &Evidence, acc: &mut SuffStats) -> Result<()> {
        let Evidence::Pair { cur_mean, prev_mean, cross_cov, prev_cov } = ev else {
            bail!("scalar coefficient needs Pair evidence");
        };
        if cur_mean.len() != prev_mean.len() {
            bail!(
                "pair evidence dims differ: {} vs {}",
                cur_mean.len(),
                prev_mean.len()
            );
        }
        let cross_mean: f64 = cur_mean
            .iter()
            .zip(*prev_mean)
            .map(|(c, p)| (*c * p.conj()).re)
            .sum();
        let prev_power: f64 = prev_mean.iter().map(|p| p.abs2()).sum();
        acc.num += cross_mean + cross_cov.trace().re;
        acc.den += prev_power + prev_cov.trace().re;
        Ok(())
    }

    fn m_step(&mut self, acc: &SuffStats) -> Result<f64> {
        let Some(ratio) = acc.ratio() else {
            bail!("scalar-coefficient M-step with no accumulated sections");
        };
        self.theta = ratio;
        Ok(self.theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn suffstats_ratio_and_discount() {
        let mut s = SuffStats::default();
        assert!(s.ratio().is_none());
        s.num = 6.0;
        s.den = 3.0;
        assert_close(s.ratio().unwrap(), 2.0, 1e-12);
        s.discount(0.5);
        assert_close(s.num, 3.0, 1e-12);
        assert_close(s.den, 1.5, 1e-12);
        let mut t = SuffStats { num: 1.0, den: 0.5 };
        t.merge(&s);
        assert_close(t.ratio().unwrap(), 2.0, 1e-12);
    }

    #[test]
    fn obs_noise_exact_on_point_posterior() {
        // posterior collapsed on the true state: residual power is the
        // exact noise sample, so sigma2' = |y - A x|^2 / count
        let n = 3;
        let x: Vec<c64> = (0..n).map(|i| c64::new(i as f64, -1.0)).collect();
        let marginal = GaussMessage::new(x.clone(), CMatrix::zeros(n, n));
        let a = CMatrix::identity(n);
        let y: Vec<c64> = x.iter().map(|v| *v + c64::new(0.2, 0.0)).collect();
        let observed: Vec<usize> = (0..n).collect();
        let mut p = ObsNoiseVar::new(1.0);
        let mut acc = SuffStats::default();
        p.accumulate(
            &Evidence::Observation { marginal: &marginal, a: &a, y: &y, observed: &observed },
            &mut acc,
        )
        .unwrap();
        let new = p.m_step(&acc).unwrap();
        assert_close(new, 0.04, 1e-12);
        assert_close(p.value(), 0.04, 1e-12);
    }

    #[test]
    fn obs_noise_adds_posterior_uncertainty() {
        // vague posterior: E|r|^2 picks up A V A^H even with r = 0
        let n = 2;
        let marginal = GaussMessage::isotropic(n, 0.5);
        let a = CMatrix::identity(n);
        let y = vec![c64::ZERO; n];
        let observed = [0usize];
        let mut p = ObsNoiseVar::new(1.0);
        let mut acc = SuffStats::default();
        p.accumulate(
            &Evidence::Observation { marginal: &marginal, a: &a, y: &y, observed: &observed },
            &mut acc,
        )
        .unwrap();
        assert_close(p.m_step(&acc).unwrap(), 0.5, 1e-12);
    }

    #[test]
    fn obs_noise_rejects_wrong_evidence() {
        let marginal = GaussMessage::isotropic(2, 1.0);
        let p = ObsNoiseVar::new(1.0);
        let mut acc = SuffStats::default();
        assert!(p.accumulate(&Evidence::Noise { marginal: &marginal }, &mut acc).is_err());
    }

    #[test]
    fn process_noise_is_marginal_power() {
        let mut m = GaussMessage::isotropic(4, 0.25); // tr V = 1.0
        m.mean[0] = c64::new(2.0, 0.0); // power 4.0
        let mut p = ProcessNoiseVar::new(1.0);
        let mut acc = SuffStats::default();
        p.accumulate(&Evidence::Noise { marginal: &m }, &mut acc).unwrap();
        // (4.0 + 1.0) / 4 components
        assert_close(p.m_step(&acc).unwrap(), 1.25, 1e-12);
    }

    #[test]
    fn m_step_floors_at_positive_variance() {
        let m = GaussMessage::isotropic(2, 0.0);
        let mut p = ProcessNoiseVar::new(1.0).with_floor(1e-6);
        let mut acc = SuffStats::default();
        p.accumulate(&Evidence::Noise { marginal: &m }, &mut acc).unwrap();
        assert_close(p.m_step(&acc).unwrap(), 1e-6, 1e-18);
    }

    #[test]
    fn scalar_coeff_recovers_exact_ratio() {
        // deterministic pair x_cur = 0.7 x_prev (zero covariances):
        // the projection is exactly 0.7
        let n = 3;
        let prev: Vec<c64> = (1..=n).map(|i| c64::new(i as f64, 0.5)).collect();
        let cur: Vec<c64> = prev.iter().map(|p| *p * 0.7).collect();
        let z = CMatrix::zeros(n, n);
        let mut p = ScalarCoeff::new(0.0);
        let mut acc = SuffStats::default();
        p.accumulate(
            &Evidence::Pair { cur_mean: &cur, prev_mean: &prev, cross_cov: &z, prev_cov: &z },
            &mut acc,
        )
        .unwrap();
        assert_close(p.m_step(&acc).unwrap(), 0.7, 1e-12);
    }

    #[test]
    fn scalar_coeff_shrinks_under_posterior_uncertainty() {
        // same means, but prev carries posterior variance: the projection
        // shrinks toward zero (den grows, num does not)
        let n = 2;
        let prev: Vec<c64> = vec![c64::new(1.0, 0.0); n];
        let cur: Vec<c64> = prev.iter().map(|p| *p * 0.7).collect();
        let z = CMatrix::zeros(n, n);
        let v = CMatrix::scaled_identity(n, 1.0);
        let mut p = ScalarCoeff::new(0.0);
        let mut acc = SuffStats::default();
        p.accumulate(
            &Evidence::Pair { cur_mean: &cur, prev_mean: &prev, cross_cov: &z, prev_cov: &v },
            &mut acc,
        )
        .unwrap();
        // num = 1.4, den = 2 + 2
        assert_close(p.m_step(&acc).unwrap(), 0.35, 1e-12);
    }

    #[test]
    fn empty_m_step_is_an_error() {
        let mut p = ObsNoiseVar::new(0.1);
        assert!(p.m_step(&SuffStats::default()).is_err());
        let mut q = ScalarCoeff::new(0.1);
        assert!(q.m_step(&SuffStats::default()).is_err());
    }
}
