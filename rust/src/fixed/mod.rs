//! S1 — Fixed-point arithmetic substrate.
//!
//! The FGP and its C66x baseline both "operate in fix point number
//! representation" (paper §V). This module provides the bit-accurate
//! number system the cycle-accurate simulator computes with:
//!
//! * [`QFormat`] — runtime-parameterizable signed Q(m.f) format
//!   (default Q5.10 in a 16-bit word, chosen so the RLS example's prior
//!   covariance `10·I` is representable);
//! * [`Fix`] — a saturating, rounding fixed-point scalar;
//! * [`CFix`] — complex fixed-point built from two [`Fix`], with the
//!   4-real-multiply complex product of Fig. 3 and the paper's complex
//!   division formula (Fig. 4, footnote 2);
//! * [`divider::Radix2Divider`] — the bit-serial radix-2 divider the
//!   PEborder uses, with its cycle cost.

pub mod divider;

pub use divider::Radix2Divider;

/// Raw-plane fixed-point primitives shared by the scalar [`Fix`]/[`CFix`]
/// ops and the data-oriented kernels in `crate::kernels`.
///
/// Every arithmetic op in the simulator bottoms out here: the scalar
/// wrappers and the struct-of-arrays kernels call the *same* functions in
/// the *same* order, which is what makes the kernel paths bitwise
/// identical to the interpreted path by construction (pinned by
/// `rust/tests/property_kernels.rs`).
pub mod raw {
    use std::cell::Cell;

    use super::{QFormat, Radix2Divider};

    thread_local! {
        /// Per-thread count of datapath saturation events (rail clamps in
        /// [`sat`] plus zero-denominator [`cdiv`] rails). Thread-local so
        /// the hot arithmetic path stays contention-free; the farm device
        /// loop drains its own thread's count into the shared
        /// `MetricsRegistry` after every dispatch.
        static SATURATIONS: Cell<u64> = const { Cell::new(0) };
    }

    #[cold]
    fn note_saturation() {
        SATURATIONS.with(|c| c.set(c.get() + 1));
    }

    /// Read **and reset** the calling thread's saturation counter. The
    /// engine layer drains this after each execution into the
    /// `fixed.saturations` registry counter, so production overflow
    /// events are observable over the `Stats` wire. Counting is always
    /// on (it reads no clocks and never changes an arithmetic result,
    /// so the invariant-7 bitwise contract is unaffected).
    pub fn take_saturations() -> u64 {
        SATURATIONS.with(|c| c.replace(0))
    }

    /// The calling thread's saturation count since the last
    /// [`take_saturations`] (tests and probes; production drains).
    pub fn saturation_count() -> u64 {
        SATURATIONS.with(|c| c.get())
    }

    /// Saturation rails + shift a [`QFormat`] induces on raw values,
    /// hoisted out of the per-element loops.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Rails {
        /// Smallest representable raw value.
        pub min: i64,
        /// Largest representable raw value.
        pub max: i64,
        /// Post-multiply shift (fraction bits).
        pub frac_bits: u32,
    }

    impl Rails {
        /// The rails of a format.
        pub fn of(fmt: QFormat) -> Rails {
            Rails { min: fmt.min_raw(), max: fmt.max_raw(), frac_bits: fmt.frac_bits }
        }
    }

    /// Clamp to the rails (the saturating output stage). Every clamp
    /// bumps the thread's saturation counter ([`take_saturations`]); the
    /// in-range fast path is branch-only.
    #[inline(always)]
    pub fn sat(x: i64, r: Rails) -> i64 {
        if x > r.max {
            note_saturation();
            r.max
        } else if x < r.min {
            note_saturation();
            r.min
        } else {
            x
        }
    }

    /// Saturating addition (the PEmult adder).
    #[inline(always)]
    pub fn add(a: i64, b: i64, r: Rails) -> i64 {
        sat(a + b, r)
    }

    /// Saturating subtraction.
    #[inline(always)]
    pub fn sub(a: i64, b: i64, r: Rails) -> i64 {
        sat(a - b, r)
    }

    /// Saturating negation.
    #[inline(always)]
    pub fn neg(a: i64, r: Rails) -> i64 {
        sat(-a, r)
    }

    /// Saturating multiply with round-to-nearest on the discarded bits
    /// (the PEmult's multiplier + rounding stage).
    #[inline(always)]
    pub fn mul(a: i64, b: i64, r: Rails) -> i64 {
        let prod = a * b;
        let half = 1i64 << (r.frac_bits - 1);
        sat((prod + half) >> r.frac_bits, r)
    }

    /// Division through the sequential radix-2 divider.
    #[inline(always)]
    pub fn div(num: i64, den: i64, r: Rails) -> i64 {
        sat(Radix2Divider::divide_raw(num, den, r.frac_bits), r)
    }

    /// Complex multiply as the PEmult executes it: 4 real multiplies,
    /// then `rr - ii` / `ri + ir` on the shared adder.
    #[inline(always)]
    pub fn cmul(ar: i64, ai: i64, br: i64, bi: i64, r: Rails) -> (i64, i64) {
        let rr = mul(ar, br, r);
        let ii = mul(ai, bi, r);
        let ri = mul(ar, bi, r);
        let ir = mul(ai, br, r);
        (sub(rr, ii, r), add(ri, ir, r))
    }

    /// Squared magnitude |z|^2 = re^2 + im^2 (PEborder abs mode).
    #[inline(always)]
    pub fn cabs2(re: i64, im: i64, r: Rails) -> i64 {
        add(mul(re, re, r), mul(im, im, r), r)
    }

    /// Complex division per the paper (Fig. 4): numerator products on the
    /// multipliers, two sequential real divisions on the single divider.
    /// A zero denominator saturates both components (hardware behaviour).
    #[inline(always)]
    pub fn cdiv(ar: i64, ai: i64, br: i64, bi: i64, r: Rails) -> (i64, i64) {
        let den = cabs2(br, bi, r);
        if den == 0 {
            // both output components rail: two saturation events
            note_saturation();
            note_saturation();
            return (r.max, r.max);
        }
        let num_re = add(mul(ar, br, r), mul(ai, bi, r), r);
        let num_im = sub(mul(ai, br, r), mul(ar, bi, r), r);
        (div(num_re, den, r), div(num_im, den, r))
    }
}

/// Signed fixed-point format: 1 sign bit + `int_bits` + `frac_bits`.
///
/// Total width must fit a 32-bit word (the hardware uses 16-bit datapaths;
/// wider formats exist for precision-ablation experiments, E9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits (excluding sign).
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// A format with the given integer/fraction split.
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        assert!(1 + int_bits + frac_bits <= 32, "QFormat must fit 32 bits");
        QFormat { int_bits, frac_bits }
    }

    /// The silicon's 16-bit default: Q5.10 (range ±32, resolution ~1e-3).
    pub const fn q5_10() -> Self {
        QFormat::new(5, 10)
    }

    /// Total word width including sign.
    pub fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable raw value.
    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    /// Smallest representable raw value (two's complement).
    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// One LSB as a real number.
    pub fn resolution(&self) -> f64 {
        (self.frac_bits as i32).pipe_exp2_neg()
    }
}

trait Exp2Neg {
    fn pipe_exp2_neg(self) -> f64;
}
impl Exp2Neg for i32 {
    fn pipe_exp2_neg(self) -> f64 {
        2f64.powi(-self)
    }
}

/// Saturating, rounding fixed-point scalar in a given [`QFormat`].
///
/// Raw values are carried in `i64` so products of two in-range values never
/// overflow before the post-multiply shift — mirroring the hardware's wide
/// accumulator in front of the saturating output stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fix {
    /// Raw scaled integer value.
    pub raw: i64,
    /// The format `raw` is scaled in.
    pub fmt: QFormat,
}

impl Fix {
    /// Quantize an f64 (round-to-nearest, saturating).
    pub fn from_f64(x: f64, fmt: QFormat) -> Self {
        let scaled = (x * (1i64 << fmt.frac_bits) as f64).round() as i64;
        Fix { raw: scaled.clamp(fmt.min_raw(), fmt.max_raw()), fmt }
    }

    /// The exact real value this fixed-point number represents.
    pub fn to_f64(self) -> f64 {
        self.raw as f64 / (1i64 << self.fmt.frac_bits) as f64
    }

    /// Zero in the given format.
    pub fn zero(fmt: QFormat) -> Self {
        Fix { raw: 0, fmt }
    }

    /// One in the given format.
    pub fn one(fmt: QFormat) -> Self {
        Fix::from_f64(1.0, fmt)
    }

    fn saturate(raw: i64, fmt: QFormat) -> Self {
        Fix { raw: raw::sat(raw, raw::Rails::of(fmt)), fmt }
    }

    /// Saturating addition (the PEmult adder).
    pub fn add(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix { raw: raw::add(self.raw, rhs.raw, raw::Rails::of(self.fmt)), fmt: self.fmt }
    }

    /// Saturating subtraction.
    pub fn sub(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix { raw: raw::sub(self.raw, rhs.raw, raw::Rails::of(self.fmt)), fmt: self.fmt }
    }

    /// Saturating multiply with round-to-nearest on the discarded bits
    /// (the PEmult's 16x16 multiplier + rounding stage).
    ///
    /// Raw values are bounded by the ≤32-bit format (|raw| ≤ 2^31), so
    /// the product fits i64 with headroom — no wide arithmetic needed on
    /// the simulator's hottest path.
    pub fn mul(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix { raw: raw::mul(self.raw, rhs.raw, raw::Rails::of(self.fmt)), fmt: self.fmt }
    }

    /// Saturating negation.
    pub fn neg(self) -> Fix {
        Fix { raw: raw::neg(self.raw, raw::Rails::of(self.fmt)), fmt: self.fmt }
    }

    /// Saturating absolute value.
    pub fn abs(self) -> Fix {
        Fix::saturate(self.raw.abs(), self.fmt)
    }

    /// True when the raw value is exactly zero.
    pub fn is_zero(self) -> bool {
        self.raw == 0
    }

    /// Division via the sequential radix-2 divider (see [`divider`]).
    /// Returns the quotient; the cycle cost is the divider's latency.
    pub fn div(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix { raw: raw::div(self.raw, rhs.raw, raw::Rails::of(self.fmt)), fmt: self.fmt }
    }
}

/// Complex fixed-point value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CFix {
    /// Real part.
    pub re: Fix,
    /// Imaginary part.
    pub im: Fix,
}

impl CFix {
    /// A complex value from parts.
    pub fn new(re: Fix, im: Fix) -> Self {
        CFix { re, im }
    }

    /// Quantize a complex f64 pair.
    pub fn from_f64(re: f64, im: f64, fmt: QFormat) -> Self {
        CFix { re: Fix::from_f64(re, fmt), im: Fix::from_f64(im, fmt) }
    }

    /// Complex zero in the given format.
    pub fn zero(fmt: QFormat) -> Self {
        CFix { re: Fix::zero(fmt), im: Fix::zero(fmt) }
    }

    /// Complex one in the given format.
    pub fn one(fmt: QFormat) -> Self {
        CFix { re: Fix::one(fmt), im: Fix::zero(fmt) }
    }

    /// The exact (re, im) this value represents.
    pub fn to_c64(self) -> (f64, f64) {
        (self.re.to_f64(), self.im.to_f64())
    }

    /// Component-wise saturating add.
    pub fn add(self, rhs: CFix) -> CFix {
        CFix { re: self.re.add(rhs.re), im: self.im.add(rhs.im) }
    }

    /// Component-wise saturating subtract.
    pub fn sub(self, rhs: CFix) -> CFix {
        CFix { re: self.re.sub(rhs.re), im: self.im.sub(rhs.im) }
    }

    /// Component-wise saturating negation.
    pub fn neg(self) -> CFix {
        CFix { re: self.re.neg(), im: self.im.neg() }
    }

    /// Complex conjugate.
    pub fn conj(self) -> CFix {
        CFix { re: self.re, im: self.im.neg() }
    }

    /// Complex multiply as the PEmult executes it: 4 real multiplies and
    /// 2 adds on one multiplier/adder pair over [`CFix::MUL_CYCLES`] cycles.
    pub fn mul(self, rhs: CFix) -> CFix {
        let fmt = self.re.fmt;
        let (re, im) =
            raw::cmul(self.re.raw, self.im.raw, rhs.re.raw, rhs.im.raw, raw::Rails::of(fmt));
        CFix { re: Fix { raw: re, fmt }, im: Fix { raw: im, fmt } }
    }

    /// Squared magnitude |z|^2 = re^2 + im^2 (PEborder abs mode).
    pub fn abs2(self) -> Fix {
        let fmt = self.re.fmt;
        Fix { raw: raw::cabs2(self.re.raw, self.im.raw, raw::Rails::of(fmt)), fmt }
    }

    /// Complex division per the paper (Fig. 4):
    /// (a+bi)/(c+di) = (ac+bd)/(c^2+d^2) + i (bc-ad)/(c^2+d^2),
    /// using one sequential divider (twice), two multipliers, one adder.
    /// A zero denominator saturates both components (hardware behaviour).
    pub fn div(self, rhs: CFix) -> CFix {
        let fmt = self.re.fmt;
        let (re, im) =
            raw::cdiv(self.re.raw, self.im.raw, rhs.re.raw, rhs.im.raw, raw::Rails::of(fmt));
        CFix { re: Fix { raw: re, fmt }, im: Fix { raw: im, fmt } }
    }

    /// True when both components are exactly zero.
    pub fn is_zero(self) -> bool {
        self.re.is_zero() && self.im.is_zero()
    }

    /// Cycles for one complex multiply on a PEmult (paper Fig. 3).
    pub const MUL_CYCLES: u64 = 4;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, proptest_cases};

    const FMT: QFormat = QFormat::q5_10();

    #[test]
    fn roundtrip_within_resolution() {
        proptest_cases(200, |rng| {
            let x = rng.range(-30.0, 30.0);
            let f = Fix::from_f64(x, FMT);
            assert!((f.to_f64() - x).abs() <= FMT.resolution());
        });
    }

    #[test]
    fn saturation_clamps() {
        let big = Fix::from_f64(1e9, FMT);
        assert_eq!(big.raw, FMT.max_raw());
        let small = Fix::from_f64(-1e9, FMT);
        assert_eq!(small.raw, FMT.min_raw());
        // saturating add holds at the rail
        assert_eq!(big.add(big).raw, FMT.max_raw());
    }

    #[test]
    fn mul_matches_f64_within_tolerance() {
        proptest_cases(500, |rng| {
            let a = rng.range(-4.0, 4.0);
            let b = rng.range(-4.0, 4.0);
            let fa = Fix::from_f64(a, FMT);
            let fb = Fix::from_f64(b, FMT);
            let got = fa.mul(fb).to_f64();
            assert_close(got, fa.to_f64() * fb.to_f64(), 4.0 * FMT.resolution());
        });
    }

    #[test]
    fn div_matches_f64_within_tolerance() {
        proptest_cases(500, |rng| {
            let a = rng.range(-8.0, 8.0);
            let b = if rng.uniform() < 0.5 { rng.range(0.5, 8.0) } else { rng.range(-8.0, -0.5) };
            let fa = Fix::from_f64(a, FMT);
            let fb = Fix::from_f64(b, FMT);
            let got = fa.div(fb).to_f64();
            assert_close(got, fa.to_f64() / fb.to_f64(), 8.0 * FMT.resolution());
        });
    }

    #[test]
    fn complex_mul_matches_f64() {
        proptest_cases(300, |rng| {
            let (a, b, c, d) = (
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
            );
            let x = CFix::from_f64(a, b, FMT);
            let y = CFix::from_f64(c, d, FMT);
            let z = x.mul(y);
            // exact complex product of the *quantized* inputs
            let (ax, bx) = x.to_c64();
            let (cy, dy) = y.to_c64();
            assert_close(z.re.to_f64(), ax * cy - bx * dy, 8.0 * FMT.resolution());
            assert_close(z.im.to_f64(), ax * dy + bx * cy, 8.0 * FMT.resolution());
        });
    }

    #[test]
    fn complex_div_matches_f64() {
        proptest_cases(300, |rng| {
            let x = CFix::from_f64(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0), FMT);
            // keep |y| well away from zero for the tolerance to be meaningful
            let y = CFix::from_f64(rng.range(1.0, 3.0), rng.range(1.0, 3.0), FMT);
            let z = x.div(y);
            let (a, b) = x.to_c64();
            let (c, d) = y.to_c64();
            let den = c * c + d * d;
            assert_close(z.re.to_f64(), (a * c + b * d) / den, 0.05);
            assert_close(z.im.to_f64(), (b * c - a * d) / den, 0.05);
        });
    }

    #[test]
    fn div_by_zero_saturates() {
        let x = CFix::from_f64(1.0, 1.0, FMT);
        let z = x.div(CFix::zero(FMT));
        assert_eq!(z.re.raw, FMT.max_raw());
    }

    #[test]
    fn conj_negates_im_only() {
        let x = CFix::from_f64(1.5, -2.5, FMT);
        let c = x.conj();
        assert_close(c.re.to_f64(), 1.5, 1e-9);
        assert_close(c.im.to_f64(), 2.5, 1e-9);
    }

    #[test]
    fn raw_plane_ops_match_scalar_wrappers_bitwise() {
        // The SoA kernels compute on raw planes via `raw::*`; the scalar
        // wrappers must be the same functions (single source of truth).
        proptest_cases(2000, |rng| {
            let r = raw::Rails::of(FMT);
            // bias toward the rails so saturation paths are exercised
            let pick = |rng: &mut crate::testutil::Rng| -> i64 {
                match rng.below(4) {
                    0 => FMT.max_raw() - (rng.next_u64() % 3) as i64,
                    1 => FMT.min_raw() + (rng.next_u64() % 3) as i64,
                    _ => (rng.next_u64() % (2 * FMT.max_raw() as u64 + 1)) as i64 + FMT.min_raw(),
                }
            };
            let (a, b, c, d) = (pick(rng), pick(rng), pick(rng), pick(rng));
            let fa = Fix { raw: a, fmt: FMT };
            let fb = Fix { raw: b, fmt: FMT };
            assert_eq!(fa.add(fb).raw, raw::add(a, b, r));
            assert_eq!(fa.sub(fb).raw, raw::sub(a, b, r));
            assert_eq!(fa.mul(fb).raw, raw::mul(a, b, r));
            assert_eq!(fa.neg().raw, raw::neg(a, r));
            let x = CFix { re: fa, im: fb };
            let y = CFix { re: Fix { raw: c, fmt: FMT }, im: Fix { raw: d, fmt: FMT } };
            let z = x.mul(y);
            assert_eq!((z.re.raw, z.im.raw), raw::cmul(a, b, c, d, r));
            assert_eq!(x.abs2().raw, raw::cabs2(a, b, r));
            let q = x.div(y);
            assert_eq!((q.re.raw, q.im.raw), raw::cdiv(a, b, c, d, r));
        });
    }

    #[test]
    fn wider_format_is_more_precise() {
        let narrow = QFormat::new(5, 8);
        let wide = QFormat::new(5, 16);
        let x = std::f64::consts::PI;
        let en = (Fix::from_f64(x, narrow).to_f64() - x).abs();
        let ew = (Fix::from_f64(x, wide).to_f64() - x).abs();
        assert!(ew < en);
    }
}
