//! S1 — Fixed-point arithmetic substrate.
//!
//! The FGP and its C66x baseline both "operate in fix point number
//! representation" (paper §V). This module provides the bit-accurate
//! number system the cycle-accurate simulator computes with:
//!
//! * [`QFormat`] — runtime-parameterizable signed Q(m.f) format
//!   (default Q5.10 in a 16-bit word, chosen so the RLS example's prior
//!   covariance `10·I` is representable);
//! * [`Fix`] — a saturating, rounding fixed-point scalar;
//! * [`CFix`] — complex fixed-point built from two [`Fix`], with the
//!   4-real-multiply complex product of Fig. 3 and the paper's complex
//!   division formula (Fig. 4, footnote 2);
//! * [`divider::Radix2Divider`] — the bit-serial radix-2 divider the
//!   PEborder uses, with its cycle cost.

pub mod divider;

pub use divider::Radix2Divider;

/// Signed fixed-point format: 1 sign bit + `int_bits` + `frac_bits`.
///
/// Total width must fit a 32-bit word (the hardware uses 16-bit datapaths;
/// wider formats exist for precision-ablation experiments, E9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits (excluding sign).
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// A format with the given integer/fraction split.
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        assert!(1 + int_bits + frac_bits <= 32, "QFormat must fit 32 bits");
        QFormat { int_bits, frac_bits }
    }

    /// The silicon's 16-bit default: Q5.10 (range ±32, resolution ~1e-3).
    pub const fn q5_10() -> Self {
        QFormat::new(5, 10)
    }

    /// Total word width including sign.
    pub fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable raw value.
    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    /// Smallest representable raw value (two's complement).
    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// One LSB as a real number.
    pub fn resolution(&self) -> f64 {
        (self.frac_bits as i32).pipe_exp2_neg()
    }
}

trait Exp2Neg {
    fn pipe_exp2_neg(self) -> f64;
}
impl Exp2Neg for i32 {
    fn pipe_exp2_neg(self) -> f64 {
        2f64.powi(-self)
    }
}

/// Saturating, rounding fixed-point scalar in a given [`QFormat`].
///
/// Raw values are carried in `i64` so products of two in-range values never
/// overflow before the post-multiply shift — mirroring the hardware's wide
/// accumulator in front of the saturating output stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fix {
    /// Raw scaled integer value.
    pub raw: i64,
    /// The format `raw` is scaled in.
    pub fmt: QFormat,
}

impl Fix {
    /// Quantize an f64 (round-to-nearest, saturating).
    pub fn from_f64(x: f64, fmt: QFormat) -> Self {
        let scaled = (x * (1i64 << fmt.frac_bits) as f64).round() as i64;
        Fix { raw: scaled.clamp(fmt.min_raw(), fmt.max_raw()), fmt }
    }

    /// The exact real value this fixed-point number represents.
    pub fn to_f64(self) -> f64 {
        self.raw as f64 / (1i64 << self.fmt.frac_bits) as f64
    }

    /// Zero in the given format.
    pub fn zero(fmt: QFormat) -> Self {
        Fix { raw: 0, fmt }
    }

    /// One in the given format.
    pub fn one(fmt: QFormat) -> Self {
        Fix::from_f64(1.0, fmt)
    }

    fn saturate(raw: i64, fmt: QFormat) -> Self {
        Fix { raw: raw.clamp(fmt.min_raw(), fmt.max_raw()), fmt }
    }

    /// Saturating addition (the PEmult adder).
    pub fn add(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix::saturate(self.raw + rhs.raw, self.fmt)
    }

    /// Saturating subtraction.
    pub fn sub(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        Fix::saturate(self.raw - rhs.raw, self.fmt)
    }

    /// Saturating multiply with round-to-nearest on the discarded bits
    /// (the PEmult's 16x16 multiplier + rounding stage).
    ///
    /// Raw values are bounded by the ≤32-bit format (|raw| ≤ 2^31), so
    /// the product fits i64 with headroom — no wide arithmetic needed on
    /// the simulator's hottest path.
    pub fn mul(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        let prod = self.raw * rhs.raw;
        let half = 1i64 << (self.fmt.frac_bits - 1);
        let rounded = (prod + half) >> self.fmt.frac_bits;
        Fix::saturate(rounded, self.fmt)
    }

    /// Saturating negation.
    pub fn neg(self) -> Fix {
        Fix::saturate(-self.raw, self.fmt)
    }

    /// Saturating absolute value.
    pub fn abs(self) -> Fix {
        Fix::saturate(self.raw.abs(), self.fmt)
    }

    /// True when the raw value is exactly zero.
    pub fn is_zero(self) -> bool {
        self.raw == 0
    }

    /// Division via the sequential radix-2 divider (see [`divider`]).
    /// Returns the quotient; the cycle cost is the divider's latency.
    pub fn div(self, rhs: Fix) -> Fix {
        debug_assert_eq!(self.fmt, rhs.fmt);
        let q = Radix2Divider::divide_raw(self.raw, rhs.raw, self.fmt.frac_bits);
        Fix::saturate(q, self.fmt)
    }
}

/// Complex fixed-point value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CFix {
    /// Real part.
    pub re: Fix,
    /// Imaginary part.
    pub im: Fix,
}

impl CFix {
    /// A complex value from parts.
    pub fn new(re: Fix, im: Fix) -> Self {
        CFix { re, im }
    }

    /// Quantize a complex f64 pair.
    pub fn from_f64(re: f64, im: f64, fmt: QFormat) -> Self {
        CFix { re: Fix::from_f64(re, fmt), im: Fix::from_f64(im, fmt) }
    }

    /// Complex zero in the given format.
    pub fn zero(fmt: QFormat) -> Self {
        CFix { re: Fix::zero(fmt), im: Fix::zero(fmt) }
    }

    /// Complex one in the given format.
    pub fn one(fmt: QFormat) -> Self {
        CFix { re: Fix::one(fmt), im: Fix::zero(fmt) }
    }

    /// The exact (re, im) this value represents.
    pub fn to_c64(self) -> (f64, f64) {
        (self.re.to_f64(), self.im.to_f64())
    }

    /// Component-wise saturating add.
    pub fn add(self, rhs: CFix) -> CFix {
        CFix { re: self.re.add(rhs.re), im: self.im.add(rhs.im) }
    }

    /// Component-wise saturating subtract.
    pub fn sub(self, rhs: CFix) -> CFix {
        CFix { re: self.re.sub(rhs.re), im: self.im.sub(rhs.im) }
    }

    /// Component-wise saturating negation.
    pub fn neg(self) -> CFix {
        CFix { re: self.re.neg(), im: self.im.neg() }
    }

    /// Complex conjugate.
    pub fn conj(self) -> CFix {
        CFix { re: self.re, im: self.im.neg() }
    }

    /// Complex multiply as the PEmult executes it: 4 real multiplies and
    /// 2 adds on one multiplier/adder pair over [`CFix::MUL_CYCLES`] cycles.
    pub fn mul(self, rhs: CFix) -> CFix {
        let rr = self.re.mul(rhs.re);
        let ii = self.im.mul(rhs.im);
        let ri = self.re.mul(rhs.im);
        let ir = self.im.mul(rhs.re);
        CFix { re: rr.sub(ii), im: ri.add(ir) }
    }

    /// Squared magnitude |z|^2 = re^2 + im^2 (PEborder abs mode).
    pub fn abs2(self) -> Fix {
        self.re.mul(self.re).add(self.im.mul(self.im))
    }

    /// Complex division per the paper (Fig. 4):
    /// (a+bi)/(c+di) = (ac+bd)/(c^2+d^2) + i (bc-ad)/(c^2+d^2),
    /// using one sequential divider (twice), two multipliers, one adder.
    pub fn div(self, rhs: CFix) -> CFix {
        let den = rhs.abs2();
        if den.is_zero() {
            // Hardware saturates on divide-by-zero; mirror that.
            let sat = Fix::saturate_max(self.re.fmt);
            return CFix { re: sat, im: sat };
        }
        let num_re = self.re.mul(rhs.re).add(self.im.mul(rhs.im));
        let num_im = self.im.mul(rhs.re).sub(self.re.mul(rhs.im));
        CFix { re: num_re.div(den), im: num_im.div(den) }
    }

    /// True when both components are exactly zero.
    pub fn is_zero(self) -> bool {
        self.re.is_zero() && self.im.is_zero()
    }

    /// Cycles for one complex multiply on a PEmult (paper Fig. 3).
    pub const MUL_CYCLES: u64 = 4;
}

impl Fix {
    fn saturate_max(fmt: QFormat) -> Fix {
        Fix { raw: fmt.max_raw(), fmt }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, proptest_cases};

    const FMT: QFormat = QFormat::q5_10();

    #[test]
    fn roundtrip_within_resolution() {
        proptest_cases(200, |rng| {
            let x = rng.range(-30.0, 30.0);
            let f = Fix::from_f64(x, FMT);
            assert!((f.to_f64() - x).abs() <= FMT.resolution());
        });
    }

    #[test]
    fn saturation_clamps() {
        let big = Fix::from_f64(1e9, FMT);
        assert_eq!(big.raw, FMT.max_raw());
        let small = Fix::from_f64(-1e9, FMT);
        assert_eq!(small.raw, FMT.min_raw());
        // saturating add holds at the rail
        assert_eq!(big.add(big).raw, FMT.max_raw());
    }

    #[test]
    fn mul_matches_f64_within_tolerance() {
        proptest_cases(500, |rng| {
            let a = rng.range(-4.0, 4.0);
            let b = rng.range(-4.0, 4.0);
            let fa = Fix::from_f64(a, FMT);
            let fb = Fix::from_f64(b, FMT);
            let got = fa.mul(fb).to_f64();
            assert_close(got, fa.to_f64() * fb.to_f64(), 4.0 * FMT.resolution());
        });
    }

    #[test]
    fn div_matches_f64_within_tolerance() {
        proptest_cases(500, |rng| {
            let a = rng.range(-8.0, 8.0);
            let b = if rng.uniform() < 0.5 { rng.range(0.5, 8.0) } else { rng.range(-8.0, -0.5) };
            let fa = Fix::from_f64(a, FMT);
            let fb = Fix::from_f64(b, FMT);
            let got = fa.div(fb).to_f64();
            assert_close(got, fa.to_f64() / fb.to_f64(), 8.0 * FMT.resolution());
        });
    }

    #[test]
    fn complex_mul_matches_f64() {
        proptest_cases(300, |rng| {
            let (a, b, c, d) = (
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
                rng.range(-3.0, 3.0),
            );
            let x = CFix::from_f64(a, b, FMT);
            let y = CFix::from_f64(c, d, FMT);
            let z = x.mul(y);
            // exact complex product of the *quantized* inputs
            let (ax, bx) = x.to_c64();
            let (cy, dy) = y.to_c64();
            assert_close(z.re.to_f64(), ax * cy - bx * dy, 8.0 * FMT.resolution());
            assert_close(z.im.to_f64(), ax * dy + bx * cy, 8.0 * FMT.resolution());
        });
    }

    #[test]
    fn complex_div_matches_f64() {
        proptest_cases(300, |rng| {
            let x = CFix::from_f64(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0), FMT);
            // keep |y| well away from zero for the tolerance to be meaningful
            let y = CFix::from_f64(rng.range(1.0, 3.0), rng.range(1.0, 3.0), FMT);
            let z = x.div(y);
            let (a, b) = x.to_c64();
            let (c, d) = y.to_c64();
            let den = c * c + d * d;
            assert_close(z.re.to_f64(), (a * c + b * d) / den, 0.05);
            assert_close(z.im.to_f64(), (b * c - a * d) / den, 0.05);
        });
    }

    #[test]
    fn div_by_zero_saturates() {
        let x = CFix::from_f64(1.0, 1.0, FMT);
        let z = x.div(CFix::zero(FMT));
        assert_eq!(z.re.raw, FMT.max_raw());
    }

    #[test]
    fn conj_negates_im_only() {
        let x = CFix::from_f64(1.5, -2.5, FMT);
        let c = x.conj();
        assert_close(c.re.to_f64(), 1.5, 1e-9);
        assert_close(c.im.to_f64(), 2.5, 1e-9);
    }

    #[test]
    fn wider_format_is_more_precise() {
        let narrow = QFormat::new(5, 8);
        let wide = QFormat::new(5, 16);
        let x = std::f64::consts::PI;
        let en = (Fix::from_f64(x, narrow).to_f64() - x).abs();
        let ew = (Fix::from_f64(x, wide).to_f64() - x).abs();
        assert!(ew < en);
    }
}
