//! The PEborder's sequential radix-2 divider (paper Fig. 4, footnote 2).
//!
//! The paper deploys **one** bit-serial divider per border PE and states
//! it "performs a sequential radix-2 division in 4 cycles". A radix-2
//! stage retires one quotient bit per cycle, so 4 cycles corresponds to a
//! 4-stage-unrolled recurrence (4 bits/cycle effective radix-16 retire
//! rate) over the 16-bit quotient. We model exactly that: a restoring
//! division producing `width` quotient bits, with latency
//! `ceil(quotient_bits / BITS_PER_CYCLE)` and `BITS_PER_CYCLE = 4` chosen
//! so a 16-bit quotient completes in the paper's 4 cycles.
//!
//! The datapath result is *bit-accurate*: the quotient equals
//! `floor(num << frac_bits / den)` with round-to-nearest, which is what
//! the restoring recurrence followed by a rounding stage produces.

/// Bit-serial radix-2 divider model.
pub struct Radix2Divider;

impl Radix2Divider {
    /// Quotient bits retired per clock cycle (4-stage unrolled radix-2).
    pub const BITS_PER_CYCLE: u32 = 4;

    /// Latency in cycles to produce a `quotient_bits`-wide quotient.
    pub fn latency_cycles(quotient_bits: u32) -> u64 {
        quotient_bits.div_ceil(Self::BITS_PER_CYCLE) as u64
    }

    /// Latency for the default 16-bit datapath — the paper's 4 cycles.
    pub fn default_latency() -> u64 {
        Self::latency_cycles(16)
    }

    /// Fixed-point division of raw values sharing `frac_bits`:
    /// returns `round(num * 2^frac_bits / den)` — exactly the quotient the
    /// restoring recurrence produces, computed in closed form. (The
    /// recurrence computes `floor(|num| << (frac+1) / |den|)` then rounds
    /// with the extra bit; integer division is that same floor, so the
    /// two are bit-identical — proven by
    /// [`tests::fast_path_matches_bit_serial_reference`]. The closed form
    /// is the simulator's hot path: 38% of CN-update time went into the
    /// bit loop before this change, see EXPERIMENTS.md §Perf.)
    pub fn divide_raw(num: i64, den: i64, frac_bits: u32) -> i64 {
        assert!(den != 0, "divide_raw: division by zero");
        let neg = (num < 0) != (den < 0);
        let dividend = (num.unsigned_abs() as u128) << (frac_bits + 1); // +1 bit for rounding
        let divisor = den.unsigned_abs() as u128;
        let quotient = dividend / divisor;
        let rounded = (quotient + 1) >> 1;
        let q = rounded as i64;
        if neg {
            -q
        } else {
            q
        }
    }

    /// The bit-serial restoring recurrence itself — the hardware's actual
    /// sequential algorithm, kept as the reference implementation for the
    /// bit-equivalence property test.
    pub fn divide_raw_bitserial(num: i64, den: i64, frac_bits: u32) -> i64 {
        assert!(den != 0, "divide_raw: division by zero");
        let neg = (num < 0) != (den < 0);
        let mut rem: u128 = 0;
        let dividend = (num.unsigned_abs() as u128) << (frac_bits + 1);
        let divisor = den.unsigned_abs() as u128;
        let total_bits = 128 - dividend.leading_zeros();
        let mut quotient: u128 = 0;

        // Restoring division: shift in one dividend bit per step, subtract
        // the divisor when it fits. Each step is one radix-2 stage.
        for i in (0..total_bits).rev() {
            rem = (rem << 1) | ((dividend >> i) & 1);
            quotient <<= 1;
            if rem >= divisor {
                rem -= divisor;
                quotient |= 1;
            }
        }
        let rounded = (quotient + 1) >> 1;
        let q = rounded as i64;
        if neg {
            -q
        } else {
            q
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::proptest_cases;

    #[test]
    fn paper_latency_is_four_cycles() {
        assert_eq!(Radix2Divider::default_latency(), 4);
    }

    #[test]
    fn latency_scales_with_width() {
        assert_eq!(Radix2Divider::latency_cycles(32), 8);
        assert_eq!(Radix2Divider::latency_cycles(8), 2);
        assert_eq!(Radix2Divider::latency_cycles(1), 1);
    }

    #[test]
    fn divide_matches_rounded_reference() {
        proptest_cases(2000, |rng| {
            let num = (rng.next_u64() % 200_000) as i64 - 100_000;
            let mut den = (rng.next_u64() % 2_000) as i64 - 1_000;
            if den == 0 {
                den = 7;
            }
            let frac = 10;
            let got = Radix2Divider::divide_raw(num, den, frac);
            let exact = (num as f64) * (1u64 << frac) as f64 / den as f64;
            let want = exact.round() as i64;
            // restoring division truncates toward zero before rounding; allow 1 ulp
            assert!(
                (got - want).abs() <= 1,
                "num={num} den={den}: got {got}, want {want}"
            );
        });
    }

    #[test]
    fn fast_path_matches_bit_serial_reference() {
        // the closed form must be BIT-IDENTICAL to the hardware recurrence
        proptest_cases(5000, |rng| {
            let num = rng.next_u64() as i64 >> (rng.below(40) + 8);
            let mut den = rng.next_u64() as i64 >> (rng.below(48) + 8);
            if den == 0 {
                den = 3;
            }
            let frac = (rng.below(20) + 4) as u32;
            assert_eq!(
                Radix2Divider::divide_raw(num, den, frac),
                Radix2Divider::divide_raw_bitserial(num, den, frac),
                "num={num} den={den} frac={frac}"
            );
        });
    }

    #[test]
    fn exact_divisions_are_exact() {
        // 6.0 / 2.0 = 3.0 in Q*.10
        assert_eq!(Radix2Divider::divide_raw(6 << 10, 2 << 10, 10), 3 << 10);
        // 1.0 / 1.0
        assert_eq!(Radix2Divider::divide_raw(1 << 10, 1 << 10, 10), 1 << 10);
        // -8 / 4 = -2
        assert_eq!(Radix2Divider::divide_raw(-(8 << 10), 4 << 10, 10), -(2 << 10));
    }

    #[test]
    #[should_panic(expected = "division by zero")]
    fn divide_by_zero_panics() {
        Radix2Divider::divide_raw(1, 0, 10);
    }
}
