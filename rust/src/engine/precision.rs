//! The precision knob: which arithmetic a session executes under.
//!
//! The paper's hardware claim (§V) is that Gaussian message updates run
//! on a **fixed-point** systolic array at full throughput; the repo's
//! golden engine is the f64 semantic reference. [`Precision`] makes the
//! choice a first-class, *declared* parameter instead of an engine
//! accident: `F64` selects the golden rules, `Fixed(fmt)` selects the
//! Q-format quantized datapath (the cycle-accurate simulator and the
//! SoA kernels, which share `fixed::raw` and are bitwise-identical by
//! construction).
//!
//! The contract (ARCHITECTURE invariant): **width never silently
//! changes** — a session, stream or serve request computes in exactly
//! the precision it declared, end to end, and every saturation event on
//! the fixed path is counted (`fixed.saturations` in the unified
//! metrics registry). The serving tier carries the knob on the wire (a
//! version-2 request field; old peers are unaffected), the farm applies
//! it per dispatch, and the conformance harness in `model/precision`
//! bounds the quantization error per width against the golden engine.

use std::fmt;

use crate::fixed::QFormat;

/// Arithmetic precision a session/stream/request executes under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// IEEE-754 double precision (the golden reference rules).
    #[default]
    F64,
    /// Q-format fixed point on the quantized datapath.
    Fixed(QFormat),
}

impl Precision {
    /// The silicon's 16-bit default fixed-point precision (Q5.10).
    pub const fn fixed_default() -> Self {
        Precision::Fixed(QFormat::q5_10())
    }

    /// Is this a fixed-point precision?
    pub fn is_fixed(&self) -> bool {
        matches!(self, Precision::Fixed(_))
    }

    /// The Q-format, when fixed.
    pub fn fmt(&self) -> Option<QFormat> {
        match self {
            Precision::F64 => None,
            Precision::Fixed(f) => Some(*f),
        }
    }

    /// Datapath word width in bits (64 for f64).
    pub fn width_bits(&self) -> u32 {
        match self {
            Precision::F64 => 64,
            Precision::Fixed(f) => f.width(),
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Precision::F64 => write!(f, "f64"),
            Precision::Fixed(q) => write!(f, "q{}.{}", q.int_bits, q.frac_bits),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_f64_and_display_names_the_width() {
        assert_eq!(Precision::default(), Precision::F64);
        assert!(!Precision::F64.is_fixed());
        assert_eq!(Precision::F64.fmt(), None);
        assert_eq!(Precision::F64.width_bits(), 64);
        assert_eq!(Precision::F64.to_string(), "f64");

        let p = Precision::fixed_default();
        assert!(p.is_fixed());
        assert_eq!(p.fmt(), Some(QFormat::q5_10()));
        assert_eq!(p.width_bits(), 16);
        assert_eq!(p.to_string(), "q5.10");
        assert_eq!(Precision::Fixed(QFormat::new(8, 20)).to_string(), "q8.20");
    }
}
