//! Streaming steady-state execution: the paper's §VI serving shape.
//!
//! The FGP's headline result (Table II) is *steady-state throughput*:
//! the program is loaded once and samples stream through the Data-in
//! port, one loop iteration per received symbol. `Session::run` cannot
//! express that — every call re-binds and re-dispatches one workload.
//! This module adds the missing surface:
//!
//! * [`StreamingWorkload`] — a recursive application declares its
//!   steady-state section **once**: which edge carries the recursive
//!   state, which edges/states are refilled per sample, and how to turn
//!   the finished stream back into a typed outcome;
//! * [`Session::run_stream`](super::Session::run_stream) — compiles the
//!   steady-state model once, then pipelines the workload's sample
//!   iterator through the cached program. On the cycle-accurate
//!   simulator a *chunk* of samples rides one `run_program` call via the
//!   compiler's memmap stream contract (the host refills the shared
//!   slots at every store handshake, exactly the §IV "HW-SW
//!   interaction"); on the golden engine samples execute one at a time;
//!   on the XLA engine a pure compound-node stream dispatches whole
//!   chunks through the AOT chain artifact, with `A = 0` identity
//!   sections padding the tail chunk;
//! * [`StreamBinder`] — the shared per-chunk data binder the session
//!   driver and the farm's sticky streams
//!   ([`crate::coordinator::FgpFarm::open_stream`]) both use.
//!
//! The per-sample binding contract mirrors [`super::workload`]: streamed
//! input edges and streamed state matrices are created in **sample
//! order** by the model builder, so sample `k` of a `chunk`-sample model
//! owns the `k`-th slice of each.
//!
//! ```
//! use fgp_repro::apps::rls::RlsProblem;
//! use fgp_repro::engine::Session;
//!
//! // The paper's channel-estimation workload, served as a stream: the
//! // model compiles once, then every training symbol is one sample.
//! let problem = RlsProblem::synthetic(4, 12, 0.01, 7);
//! let mut session = Session::golden();
//! let report = session.run_stream(&problem).unwrap();
//! assert_eq!(report.samples, 12);
//! assert!(report.outcome.rel_mse.is_finite());
//! ```

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::compiler::CompileOptions;
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::schedule::StepOp;
use crate::gmp::{FactorGraph, MsgId, Schedule};

use super::session::EngineKind;
use super::workload::{preload_id, split_inputs};

/// Default number of samples [`Session::run_stream`](super::Session::run_stream)
/// pipelines per compiled-program dispatch on program engines. Streamed
/// edges/states share one physical slot each, so chunk size costs no
/// message memory; it only sets how much per-dispatch overhead is
/// amortized.
pub const DEFAULT_STREAM_CHUNK: usize = 64;

/// Per-sample data bound to a stream's steady-state section.
#[derive(Clone, Debug)]
pub struct StreamSample {
    /// Messages for the sample's streamed input edges, in section order.
    pub messages: Vec<GaussMessage>,
    /// Matrices for the sample's streamed states, in stream order.
    pub states: Vec<CMatrix>,
}

/// A finished stream, as handed to [`StreamingWorkload::stream_outcome`].
#[derive(Clone, Debug)]
pub struct StreamRun {
    /// Recursive state after the final sample.
    pub final_state: GaussMessage,
    /// Recursive state at every dispatch boundary. With
    /// [`StreamingWorkload::max_chunk`] `== 1` (state-dependent apps)
    /// this is the per-sample posterior trace; chunked streams observe
    /// one boundary per chunk.
    pub boundaries: Vec<GaussMessage>,
    /// Samples consumed.
    pub samples: u64,
}

/// A resumable snapshot of a stream's recursive per-sample state: the
/// serialization unit behind the serve tier's checkpoint/failover path
/// (`rust/src/serve/`) and
/// [`Session::run_stream_from`](super::Session::run_stream_from).
///
/// The invariant that makes this safe to restore **anywhere** — another
/// device of an [`crate::coordinator::FgpFarm`], another process via
/// the wire codec — is chunk invariance: on every engine in this crate,
/// folding the same sample sequence through any chunk partitioning
/// yields bitwise-identical recursive states (exact f64 on golden;
/// quantize∘quantize == quantize on the fixed-point simulator, pinned
/// by `rust/tests/integration_streaming.rs`). A checkpoint taken at any
/// dispatch boundary therefore resumes bitwise-identically regardless
/// of how the remaining samples get re-chunked.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamCheckpoint {
    /// [`StreamingWorkload::stream_name`] of the checkpointed stream
    /// (restore validates it against the resuming workload).
    pub stream_name: String,
    /// Samples already folded into `state`.
    pub samples: u64,
    /// Recursive state after sample `samples - 1`.
    pub state: GaussMessage,
    /// Dispatch-boundary states observed so far (carried so a resumed
    /// [`StreamRun::boundaries`] matches an uninterrupted run's).
    pub boundaries: Vec<GaussMessage>,
}

/// Result of [`Session::run_stream`](super::Session::run_stream): the
/// typed outcome plus everything the serving and benchmark layers report.
#[derive(Clone, Debug)]
pub struct StreamReport<O> {
    /// The workload's typed stream outcome.
    pub outcome: O,
    /// Recursive state after the final sample (hand it to a follow-up
    /// stream to keep filtering).
    pub final_state: GaussMessage,
    /// Samples consumed.
    pub samples: u64,
    /// Dispatches issued (chunks, including a short tail).
    pub chunks: u64,
    /// Steady-state chunk size the engine chose.
    pub chunk: usize,
    /// Simulated device cycles (0 on engines without a cycle model).
    pub cycles: u64,
    /// Sections (store handshakes) the device committed.
    pub sections: u64,
    /// Programs compiled for this stream (0 on non-program engines; at
    /// most 2 — steady-state chunk + tail — on the simulator).
    pub compiles: u64,
    /// Stream programs served from the session cache instead.
    pub cache_hits: u64,
    /// Engine that served the stream.
    pub engine: EngineKind,
}

impl<O> StreamReport<O> {
    /// Simulated device cycles per sample (0 on engines without a cycle
    /// model).
    pub fn cycles_per_sample(&self) -> u64 {
        self.cycles / self.samples.max(1)
    }
}

/// A recursive application on the streaming surface.
///
/// The contract [`Session::run_stream`](super::Session::run_stream) and
/// [`crate::coordinator::FgpFarm::open_stream`] rely on:
///
/// 1. [`stream_model`](Self::stream_model)`(chunk)` builds the
///    steady-state model of `chunk` consecutive samples: the recursive
///    state enters on the preloaded input edge labelled
///    [`state_label`](Self::state_label), each sample's data rides
///    streamed input edges / streamed states **created in sample
///    order**, and exactly one edge — the state after the last sample —
///    is marked as the output.
/// 2. [`next_sample`](Self::next_sample)`(k, state)` yields sample `k`'s
///    data or `None` at end of stream. `state` is the most recent
///    recursive state the host has observed; it lags up to `chunk - 1`
///    samples on chunked engines, so apps whose binding depends on the
///    *exact* per-sample state (relinearization) must declare
///    [`max_chunk`](Self::max_chunk)`() == 1`.
/// 3. [`stream_outcome`](Self::stream_outcome) interprets the finished
///    [`StreamRun`].
///
/// Method names are disjoint from [`super::Workload`]'s on purpose: an
/// app can implement both traits and callers can import both without
/// ambiguity.
pub trait StreamingWorkload {
    /// Typed result of a finished stream.
    type StreamOutcome;

    /// Short identifier (diagnostics, cache reports).
    fn stream_name(&self) -> &str;

    /// State dimension (must match the device size).
    fn state_dim(&self) -> usize;

    /// Build the steady-state model of `chunk` consecutive samples.
    fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)>;

    /// Label of the recursive state's preloaded input edge.
    fn state_label(&self) -> &str {
        "msg_prior"
    }

    /// Constant preloaded inputs (process noise, priors that are not the
    /// recursive state), bound once per dispatch, by edge label.
    fn constant_inputs(&self) -> Vec<(String, GaussMessage)> {
        Vec::new()
    }

    /// Initial recursive state.
    fn initial_state(&self) -> GaussMessage;

    /// Sample `k`'s data, or `None` at end of stream. `state` is the
    /// latest host-observed recursive state (see the trait docs for the
    /// chunk-lag contract).
    fn next_sample(&self, k: usize, state: &GaussMessage) -> Result<Option<StreamSample>>;

    /// Largest chunk the driver may pipeline per dispatch; `1` when
    /// sample binding is state-dependent.
    fn max_chunk(&self) -> usize {
        DEFAULT_STREAM_CHUNK
    }

    /// Compiler options for program engines.
    fn stream_compile_options(&self) -> CompileOptions {
        CompileOptions::default()
    }

    /// Interpret the finished stream.
    fn stream_outcome(&self, run: &StreamRun) -> Result<Self::StreamOutcome>;
}

/// Reusable per-chunk binder for a stream's steady-state model: built
/// once per chunk shape, it rebinds the recursive state, the constant
/// inputs and every sample's streamed messages/states in place, so the
/// steady-state loop allocates no fresh model per dispatch.
pub struct StreamBinder {
    /// The chunk model's factor graph (streamed states rebound in place).
    pub graph: FactorGraph,
    /// The chunk model's schedule.
    pub schedule: Schedule,
    /// Input bindings, refreshed by [`StreamBinder::bind`].
    pub inputs: HashMap<MsgId, GaussMessage>,
    chunk: usize,
    n: usize,
    state_mid: MsgId,
    /// Streamed input message ids, sample-major (virtual-id order).
    streamed_mids: Vec<MsgId>,
    /// Streamed state indices into `graph.states`, sample-major.
    streamed_sids: Vec<usize>,
    per_sample_msgs: usize,
    per_sample_states: usize,
}

impl StreamBinder {
    /// Build the binder for `chunk` samples of `w`'s stream.
    pub fn build<W: StreamingWorkload + ?Sized>(w: &W, chunk: usize) -> Result<Self> {
        if chunk == 0 {
            bail!("stream chunk must be at least 1");
        }
        let (graph, schedule) = w.stream_model(chunk)?;
        if schedule.outputs.len() != 1 {
            bail!(
                "stream '{}' must mark exactly one output edge (the final state), found {}",
                w.stream_name(),
                schedule.outputs.len()
            );
        }
        let state_mid = preload_id(&graph, &schedule, w.state_label())?;
        let (_, streamed) = split_inputs(&graph, &schedule);
        let streamed_mids: Vec<MsgId> = streamed.iter().map(|(m, _)| *m).collect();
        let streamed_sids: Vec<usize> = graph
            .state_stream_groups
            .iter()
            .enumerate()
            .filter(|(_, g)| g.is_some())
            .map(|(i, _)| i)
            .collect();
        if streamed_mids.len() % chunk != 0 || streamed_sids.len() % chunk != 0 {
            bail!(
                "stream '{}' model has {} streamed edges / {} streamed states, not a multiple of chunk {}",
                w.stream_name(),
                streamed_mids.len(),
                streamed_sids.len(),
                chunk
            );
        }
        let mut inputs = HashMap::new();
        for (label, msg) in w.constant_inputs() {
            inputs.insert(preload_id(&graph, &schedule, &label)?, msg);
        }
        let n = w.state_dim();
        Ok(StreamBinder {
            per_sample_msgs: streamed_mids.len() / chunk,
            per_sample_states: streamed_sids.len() / chunk,
            graph,
            schedule,
            inputs,
            chunk,
            n,
            state_mid,
            streamed_mids,
            streamed_sids,
        })
    }

    /// Samples this binder's model spans.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Rebind the recursive state plus one chunk of samples in place.
    /// `samples.len()` must equal [`StreamBinder::chunk`].
    pub fn bind(&mut self, state: &GaussMessage, samples: &[StreamSample]) -> Result<()> {
        if samples.len() != self.chunk {
            bail!(
                "binder spans {} samples but {} were supplied",
                self.chunk,
                samples.len()
            );
        }
        self.inputs.insert(self.state_mid, state.clone());
        for (k, s) in samples.iter().enumerate() {
            if s.messages.len() != self.per_sample_msgs
                || s.states.len() != self.per_sample_states
            {
                bail!(
                    "sample {k} carries {} messages / {} states but the model expects {} / {} per sample",
                    s.messages.len(),
                    s.states.len(),
                    self.per_sample_msgs,
                    self.per_sample_states
                );
            }
            for (j, m) in s.messages.iter().enumerate() {
                self.inputs
                    .insert(self.streamed_mids[k * self.per_sample_msgs + j], m.clone());
            }
            for (j, a) in s.states.iter().enumerate() {
                self.graph.states[self.streamed_sids[k * self.per_sample_states + j]] = a.clone();
            }
        }
        Ok(())
    }

    /// True when the model is a pure compound-observation chain with one
    /// streamed message and one streamed state per sample. Such a chunk
    /// may be padded with identity sections — `A = 0` makes the gain
    /// `V_X A^H (A V_X A^H + V_Y)^-1` exactly zero, so a padded section
    /// leaves the recursive state untouched (pinned by
    /// `rust/tests/integration_streaming.rs`). The XLA engine uses this
    /// to ship tail chunks through the fixed-length chain artifact.
    pub fn paddable(&self) -> bool {
        self.per_sample_msgs == 1
            && self.per_sample_states == 1
            && self
                .schedule
                .steps
                .iter()
                .all(|s| matches!(s.op, StepOp::CompoundObservation { .. }))
    }

    /// An identity-update pad sample: `A = 0`, a zero-mean observation
    /// with the same covariance as `like`'s (the chain artifact requires
    /// one isotropic observation covariance across the whole chunk).
    pub fn pad_sample(&self, like: &StreamSample) -> StreamSample {
        let cov = like.messages[0].cov.clone();
        StreamSample {
            messages: vec![GaussMessage::new(vec![c64::ZERO; self.n], cov)],
            states: vec![CMatrix::zeros(self.n, self.n)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::rls::RlsProblem;

    fn sample_for(p: &RlsProblem, k: usize) -> StreamSample {
        p.next_sample(k, &p.initial_state()).unwrap().expect("sample in range")
    }

    #[test]
    fn build_rejects_zero_chunk() {
        let p = RlsProblem::synthetic(4, 8, 0.02, 3);
        let err = StreamBinder::build(&p, 0).unwrap_err();
        assert!(err.to_string().contains("at least 1"), "{err:#}");
    }

    #[test]
    fn bind_rejects_wrong_sample_count() {
        let p = RlsProblem::synthetic(4, 8, 0.02, 3);
        let mut binder = StreamBinder::build(&p, 4).unwrap();
        let state = p.initial_state();
        let samples: Vec<StreamSample> = (0..2).map(|k| sample_for(&p, k)).collect();
        let err = binder.bind(&state, &samples).unwrap_err();
        assert!(
            err.to_string().contains("binder spans 4 samples but 2 were supplied"),
            "{err:#}"
        );
    }

    #[test]
    fn bind_rejects_wrong_message_arity() {
        let p = RlsProblem::synthetic(4, 8, 0.02, 3);
        let mut binder = StreamBinder::build(&p, 2).unwrap();
        let state = p.initial_state();
        let good = sample_for(&p, 0);
        // sample 1 carries twice the messages the model expects
        let mut bad = sample_for(&p, 1);
        bad.messages.push(bad.messages[0].clone());
        let err = binder.bind(&state, &[good, bad]).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("sample 1 carries 2 messages / 1 states"), "{text}");
        assert!(text.contains("expects 1 / 1 per sample"), "{text}");
    }

    #[test]
    fn bind_rejects_wrong_state_arity() {
        let p = RlsProblem::synthetic(4, 8, 0.02, 3);
        let mut binder = StreamBinder::build(&p, 2).unwrap();
        let state = p.initial_state();
        // sample 0 carries no state matrix at all
        let mut bad = sample_for(&p, 0);
        bad.states.clear();
        let good = sample_for(&p, 1);
        let err = binder.bind(&state, &[bad, good]).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("sample 0 carries 1 messages / 0 states"), "{text}");
    }

    #[test]
    fn bind_accepts_matching_arity_after_rejection() {
        // a rejected bind leaves the binder reusable: the same binder
        // accepts a well-shaped chunk afterwards
        let p = RlsProblem::synthetic(4, 8, 0.02, 3);
        let mut binder = StreamBinder::build(&p, 2).unwrap();
        let state = p.initial_state();
        let mut bad = sample_for(&p, 0);
        bad.states.clear();
        assert!(binder.bind(&state, &[bad, sample_for(&p, 1)]).is_err());
        let good: Vec<StreamSample> = (0..2).map(|k| sample_for(&p, k)).collect();
        binder.bind(&state, &good).unwrap();
        assert!(binder.paddable());
    }
}
