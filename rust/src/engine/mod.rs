//! S12 — The unified workload/engine execution surface.
//!
//! The paper's thesis is that *one* processor serves many GMP algorithms
//! (§I: "RLS, linear MMSE equalization, and Kalman filtering can be
//! expressed with Gaussian message-passing on a factor graph"). This
//! module is that thesis as an API: every application describes itself
//! once — a [`FactorGraph`](crate::gmp::FactorGraph) + a
//! [`Schedule`](crate::gmp::Schedule) plus the host-side data bound to
//! the graph's input edges — and any [`Engine`] executes that same model:
//!
//! * [`GoldenEngine`] — the f64 node rules (the semantic reference);
//! * [`FgpSimEngine`] — the cycle-accurate fixed-point simulator, driven
//!   through the compiler's memmap preload/stream/output contract;
//! * [`XlaEngine`] — the PJRT artifacts (the Pallas compound-node kernel),
//!   with f64 host glue for the node types the artifact set doesn't cover.
//!
//! A [`Session`] owns one engine plus a **compiled-program cache** keyed
//! by the graph's structural signature: repeated runs of the same
//! workload *shape* (any data) reuse the compiled FGP program instead of
//! recompiling — the hit/miss counters are observable via
//! [`Session::cache_stats`].
//!
//! Recursive applications additionally implement
//! [`StreamingWorkload`] and serve **steady state** through
//! [`Session::run_stream`]: the model compiles once and samples stream
//! through the resident program (the paper's §VI throughput shape — see
//! [`stream`] for the contract and `rust/benches/table2_throughput.rs`
//! for the measured msgs/sec trajectory in `BENCH_throughput.json`).
//!
//! ```no_run
//! use fgp_repro::apps::rls::RlsProblem;
//! use fgp_repro::engine::Session;
//! use fgp_repro::fgp::FgpConfig;
//!
//! let problem = RlsProblem::synthetic(4, 16, 0.01, 42);
//! let mut golden = Session::golden();
//! let mut device = Session::fgp_sim(FgpConfig::default());
//! let reference = golden.run(&problem).unwrap();
//! let measured = device.run(&problem).unwrap();
//! assert!(measured.quality < reference.quality + 0.2);
//! println!("cycles/section = {}", measured.cycles_per_section);
//! ```

pub mod precision;
pub mod session;
pub mod stream;
pub mod workload;

pub use precision::Precision;
pub use session::{
    CacheStats, Engine, EngineKind, FgpSimEngine, GoldenEngine, RunReport, Session, XlaEngine,
};
pub use stream::{
    StreamBinder, StreamCheckpoint, StreamReport, StreamRun, StreamSample, StreamingWorkload,
    DEFAULT_STREAM_CHUNK,
};
pub use workload::{bind_streamed, edge_label, preload_id, split_inputs, Execution, Workload};
