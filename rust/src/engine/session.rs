//! [`Session`]: one execution surface over interchangeable engines.
//!
//! A session owns an [`Engine`] and a compiled-program cache. Callers
//! hand it any [`Workload`]; the session builds the model, binds the
//! data, compiles (or fetches) the FGP program when the engine needs
//! one, executes, and wraps the typed outcome in a [`RunReport`].
//!
//! The cache is keyed by the graph's **structural signature** — edge
//! dims/roles/stream groups, node kinds with their state wiring, and the
//! compile options — never by data values: two runs of the same workload
//! shape share one compiled program, which is what lets a serving
//! deployment amortize compilation across millions of requests (and what
//! `FgpSimBackend`, `FgpFarm` and every old `run_on_fgp` used to redo
//! from scratch on each construction).

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::compiler::{compile, CompileOptions, CompileStats, CompiledProgram};
use crate::fgp::{Fgp, FgpConfig, MessageMemory, Profiler, RunStats, StateMemory};
use crate::fixed::QFormat;
use crate::gmp::graph::StateId;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::gmp::schedule::StepOp;
use crate::gmp::{nodes, FactorGraph, MsgId, NodeKind, Schedule};
use crate::isa::{Instr, Opcode};
use crate::obs::{Telemetry, TraceContext};
use crate::runtime::RuntimeClient;

use super::stream::{
    StreamBinder, StreamCheckpoint, StreamReport, StreamRun, StreamSample, StreamingWorkload,
};
use super::workload::{Execution, Workload};

/// Which engine a session drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// f64 golden node rules (semantic reference).
    Golden,
    /// Cycle-accurate fixed-point FGP simulator.
    FgpSim,
    /// PJRT/XLA artifacts (Pallas compound-node kernel).
    Xla,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Golden => write!(f, "golden"),
            EngineKind::FgpSim => write!(f, "fgp-sim"),
            EngineKind::Xla => write!(f, "xla"),
        }
    }
}

/// An execution engine: everything that can run a workload model.
pub trait Engine {
    /// Which engine this is (reporting, routing, conformance).
    fn kind(&self) -> EngineKind;

    /// Does this engine execute a compiled FGP program? (Controls whether
    /// [`Session`] consults the program cache.)
    fn needs_program(&self) -> bool {
        false
    }

    /// Fixed device dimension, if the engine has one (the FGP simulator).
    fn device_n(&self) -> Option<usize> {
        None
    }

    /// The arithmetic precision this engine computes in. Engines without
    /// a quantized datapath are the f64 reference.
    fn precision(&self) -> super::precision::Precision {
        super::precision::Precision::F64
    }

    /// Switch the engine's fixed-point format. Returns `true` when the
    /// engine honours the request (the FGP simulator); engines without a
    /// quantized datapath return `false` so callers can refuse instead
    /// of silently computing at a different width.
    fn set_fixed_format(&mut self, _fmt: QFormat) -> bool {
        false
    }

    /// Samples per dispatch [`Session::run_stream`] should pipeline
    /// through this engine, bounded by the workload's declared ceiling
    /// `app_max`. Program engines amortize one compiled chunk program
    /// over the whole chunk; engines without a program default to
    /// sample-at-a-time.
    fn stream_chunk(&self, app_max: usize) -> usize {
        if self.needs_program() {
            app_max.max(1)
        } else {
            1
        }
    }

    /// Attach (or clear) the telemetry handle + parent context for the
    /// next execution, so the engine can record its internal phases as
    /// children of the caller's span. Engines without internal phases
    /// ignore it — telemetry must never change results (invariant 7).
    fn set_trace(&mut self, _trace: Option<(Arc<Telemetry>, TraceContext)>) {}

    /// Execute a model against the bound inputs. `program` is the cached
    /// compiled program when [`Engine::needs_program`] is true (shared
    /// `Arc` so engines can identity-compare against what they already
    /// have loaded).
    fn execute(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        program: Option<&Arc<CompiledProgram>>,
        inputs: &HashMap<MsgId, GaussMessage>,
    ) -> Result<Execution>;
}

// ---------------------------------------------------------------------
// Golden engine
// ---------------------------------------------------------------------

/// The f64 reference engine: executes the schedule with the golden node
/// rules (direct solve by default; set `faddeev` to mirror the device's
/// elimination order bit-for-bit in f64).
#[derive(Default)]
pub struct GoldenEngine {
    /// Mirror the device's Faddeev elimination order in f64.
    pub faddeev: bool,
}

impl Engine for GoldenEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Golden
    }

    fn execute(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        _program: Option<&Arc<CompiledProgram>>,
        inputs: &HashMap<MsgId, GaussMessage>,
    ) -> Result<Execution> {
        let env = schedule.execute_golden(graph, inputs, self.faddeev)?;
        let outputs = collect_outputs(schedule, |mid| env.get(mid).cloned())?;
        Ok(Execution { outputs, stats: RunStats::default() })
    }
}

// ---------------------------------------------------------------------
// FGP simulator engine
// ---------------------------------------------------------------------

/// The cycle-accurate device: loads the compiled program, preloads the
/// memmap's resident messages/states, streams sectioned inputs through
/// the Data-in port, and reads the outputs back. The PM image is only
/// re-serialized and reloaded when the program actually changes — on a
/// serving hot path firing the same cached program per request, loading
/// happens once.
pub struct FgpSimEngine {
    fgp: Fgp,
    /// Program currently resident in the PM (identity-compared by Arc).
    loaded: Option<Arc<CompiledProgram>>,
    /// Telemetry handle + parent span for the next run (see
    /// [`Engine::set_trace`]); attaches the instruction profiler and
    /// emits per-opcode phase spans when enabled.
    trace: Option<(Arc<Telemetry>, TraceContext)>,
}

impl FgpSimEngine {
    /// Engine over a fresh simulator with the given configuration.
    pub fn new(config: FgpConfig) -> Self {
        FgpSimEngine { fgp: Fgp::new(config), loaded: None, trace: None }
    }

    /// The simulator's configuration.
    pub fn config(&self) -> &FgpConfig {
        &self.fgp.config
    }

    /// Lifetime simulated cycles across all runs.
    pub fn total_cycles(&self) -> u64 {
        self.fgp.total_cycles()
    }
}

/// Per-slot streaming plan: element `i` must sit in `slot` while the
/// schedule executes step `consume_at[i]`; the host stages it at every
/// store handshake from the death of element `i-1` onward.
struct StreamPlan<T> {
    slot: u8,
    consume_at: Vec<usize>,
    values: Vec<T>,
}

impl<T> StreamPlan<T> {
    /// Element to stage when `section` store handshakes have committed
    /// (i.e. the next step to execute is `section`).
    fn staged(&self, section: usize) -> Option<&T> {
        self.consume_at
            .iter()
            .position(|&c| c >= section)
            .map(|i| &self.values[i])
    }
}

impl Engine for FgpSimEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::FgpSim
    }

    fn needs_program(&self) -> bool {
        true
    }

    fn set_trace(&mut self, trace: Option<(Arc<Telemetry>, TraceContext)>) {
        self.trace = trace;
    }

    fn device_n(&self) -> Option<usize> {
        Some(self.fgp.config.n)
    }

    fn precision(&self) -> super::precision::Precision {
        super::precision::Precision::Fixed(self.fgp.config.fmt)
    }

    fn set_fixed_format(&mut self, fmt: QFormat) -> bool {
        if self.fgp.config.fmt != fmt {
            // The format is baked into the memories and the systolic
            // array at construction, so honouring the switch means
            // rebuilding the device; the PM image must be reloaded on
            // the next execute. The program cache is unaffected — the
            // structural signature has no format component.
            let mut cfg = self.fgp.config;
            cfg.fmt = fmt;
            self.fgp = Fgp::new(cfg);
            self.loaded = None;
        }
        true
    }

    fn execute(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        program: Option<&Arc<CompiledProgram>>,
        inputs: &HashMap<MsgId, GaussMessage>,
    ) -> Result<Execution> {
        let compiled = program.context("the FGP engine requires a compiled program")?;
        let n = self.fgp.config.n;
        let resident = self
            .loaded
            .as_ref()
            .map_or(false, |p| Arc::ptr_eq(p, compiled));
        if !resident {
            self.loaded = None;
            self.fgp
                .pm
                .load(&compiled.program.to_image())
                .context("loading program image")?;
            self.loaded = Some(Arc::clone(compiled));
        }

        // resident messages and states
        for (mid, slot) in &compiled.memmap.preloads {
            let msg = inputs
                .get(mid)
                .with_context(|| format!("no binding for preloaded input message {}", mid.0))?;
            self.fgp.msgmem.write_message(*slot, msg);
        }
        for (sid, slot) in &compiled.memmap.state_preloads {
            // states past the graph's table are compiler-materialized
            // identities (additive/equality lowering)
            let m = graph
                .states
                .get(sid.0)
                .cloned()
                .unwrap_or_else(|| CMatrix::identity(n));
            self.fgp.statemem.write_matrix(*slot, &m);
        }

        // streaming plans: element i of a stream group must be resident
        // in the shared slot when its consuming step executes. One pass
        // over the schedule finds every first-consumption step — a long
        // chain's plan build is O(steps), which the steady-state stream
        // path (`Session::run_stream`) pays once per chunk.
        let mut msg_consumed_at: HashMap<MsgId, usize> = HashMap::new();
        let mut state_consumed_at: HashMap<StateId, usize> = HashMap::new();
        for (i, step) in schedule.steps.iter().enumerate() {
            for mid in step.op.inputs() {
                msg_consumed_at.entry(mid).or_insert(i);
            }
            if let Some(sid) = step.op.state() {
                state_consumed_at.entry(sid).or_insert(i);
            }
        }
        let consume_msg = |mid: &MsgId| {
            msg_consumed_at
                .get(mid)
                .copied()
                .with_context(|| format!("streamed message {} is never consumed", mid.0))
        };
        let consume_state = |sid: &StateId| {
            state_consumed_at
                .get(sid)
                .copied()
                .with_context(|| format!("streamed state {} is never consumed", sid.0))
        };
        // Plans borrow the caller's inputs/graph directly: a steady-state
        // stream chunk stages thousands of messages, and cloning each
        // GaussMessage/CMatrix per chunk was pure allocator traffic.
        let mut msg_plans: Vec<StreamPlan<&GaussMessage>> = Vec::new();
        for (_, slot, ids) in &compiled.memmap.streams {
            let mut entries: Vec<(usize, &GaussMessage)> = Vec::with_capacity(ids.len());
            for mid in ids {
                let at = consume_msg(mid)?;
                let msg = inputs
                    .get(mid)
                    .with_context(|| format!("no binding for streamed input message {}", mid.0))?;
                entries.push((at, msg));
            }
            entries.sort_by_key(|(at, _)| *at);
            msg_plans.push(StreamPlan {
                slot: *slot,
                consume_at: entries.iter().map(|(at, _)| *at).collect(),
                values: entries.into_iter().map(|(_, m)| m).collect(),
            });
        }
        let mut state_plans: Vec<StreamPlan<&CMatrix>> = Vec::new();
        for (_, slot, ids) in &compiled.memmap.state_streams {
            let mut entries: Vec<(usize, &CMatrix)> = Vec::with_capacity(ids.len());
            for sid in ids {
                let at = consume_state(sid)?;
                let m = graph
                    .states
                    .get(sid.0)
                    .with_context(|| format!("streamed state {} not in the graph", sid.0))?;
                entries.push((at, m));
            }
            entries.sort_by_key(|(at, _)| *at);
            state_plans.push(StreamPlan {
                slot: *slot,
                consume_at: entries.iter().map(|(at, _)| *at).collect(),
                values: entries.into_iter().map(|(_, m)| m).collect(),
            });
        }

        let streaming = !msg_plans.is_empty() || !state_plans.is_empty();
        let mut feed =
            move |section: usize, mem: &mut MessageMemory, st: &mut StateMemory| -> bool {
                if !streaming {
                    return true;
                }
                let mut live = false;
                for p in &msg_plans {
                    if let Some(msg) = p.staged(section) {
                        mem.write_message(p.slot, msg);
                        live = true;
                    }
                }
                for p in &state_plans {
                    if let Some(m) = p.staged(section) {
                        st.write_matrix(p.slot, m);
                        live = true;
                    }
                }
                live
            };

        let id = match compiled.program.instrs.first() {
            Some(Instr::Prg { id }) => *id,
            _ => 1,
        };
        // run_program_profiled(.., None) and run_program are the same
        // code path, so attaching the profiler cannot change results —
        // only the per-opcode cycle accounting rides along (invariant 7)
        let profiling = self.trace.as_ref().map_or(false, |(t, _)| t.enabled());
        let t0 = self.trace.as_ref().map_or(0, |(t, _)| t.now_ns());
        let mut prof = if profiling { Some(Profiler::new(0)) } else { None };
        let stats = self.fgp.run_program_profiled(id, &mut feed, prof.as_mut())?;
        if let Some(((tel, parent), prof)) = self.trace.as_ref().zip(prof.as_ref()) {
            // one span for the device run, then its per-opcode phases
            // rescaled from device cycles onto the wall clock at the
            // paper's 130 MHz, laid end to end inside the run window
            let run_ctx = parent.child();
            tel.span(run_ctx, parent.span_id, "fgp.run", "fgp", t0, stats.cycles);
            let ns_per_cycle = 1000.0 / crate::paper::FGP_FREQ_MHZ;
            let mut cursor = t0;
            for (name, metric, op) in [
                ("fgp.mma", "fgp.cycles.mma", Opcode::Mma),
                ("fgp.mms", "fgp.cycles.mms", Opcode::Mms),
                ("fgp.fad", "fgp.cycles.fad", Opcode::Fad),
                ("fgp.smm", "fgp.cycles.smm", Opcode::Smm),
            ] {
                let s = prof.stats(op);
                if s.count == 0 {
                    continue;
                }
                tel.registry().add(metric, s.cycles);
                let dur = (s.cycles as f64 * ns_per_cycle) as u64;
                tel.span_at(run_ctx.child(), run_ctx.span_id, name, "fgp", cursor, dur, s.cycles);
                cursor += dur;
            }
        }

        let outputs = collect_outputs(schedule, |mid| {
            compiled
                .memmap
                .outputs
                .iter()
                .find(|(m, _)| m == mid)
                .map(|(_, slot)| self.fgp.msgmem.read_message(*slot))
        })?;
        Ok(Execution { outputs, stats })
    }
}

// ---------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------

/// The PJRT engine. Compound-observation updates dispatch the Pallas
/// `cn_update` artifact; a pure compound-node chain whose length matches
/// the AOT-baked `rls_chain` artifact goes out as ONE fused dispatch.
/// Node types outside the artifact set (multiply/add/equality glue) run
/// on the host in f64 — the artifacts cover the §II datapath kernel, not
/// the whole node zoo.
pub struct XlaEngine {
    rt: Rc<RuntimeClient>,
}

impl XlaEngine {
    /// Engine owning its PJRT runtime.
    pub fn new(rt: RuntimeClient) -> Self {
        XlaEngine { rt: Rc::new(rt) }
    }

    /// Share one thread-affine PJRT client between engine and caller.
    pub fn shared(rt: Rc<RuntimeClient>) -> Self {
        XlaEngine { rt }
    }

    /// The underlying PJRT runtime.
    pub fn runtime(&self) -> &RuntimeClient {
        &self.rt
    }

    /// One fused dispatch when the model is exactly the artifact's chain.
    fn try_fused_chain(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
        inputs: &HashMap<MsgId, GaussMessage>,
    ) -> Result<Option<Execution>> {
        let sections = match self.rt.manifest.entry("rls_chain").and_then(|e| e.leading_dim()) {
            Some(s) => s,
            None => return Ok(None),
        };
        if schedule.steps.len() != sections || schedule.outputs.len() != 1 {
            return Ok(None);
        }
        let last_out = schedule.steps.last().map(|s| s.out);
        if schedule.outputs.first().map(|(m, _)| *m) != last_out {
            return Ok(None);
        }
        let mut prev: Option<MsgId> = None;
        let mut a_seq = Vec::with_capacity(sections);
        let mut y_seq = Vec::with_capacity(sections);
        let mut prior: Option<&GaussMessage> = None;
        for step in &schedule.steps {
            let StepOp::CompoundObservation { x, y, a } = &step.op else {
                return Ok(None);
            };
            match prev {
                None => prior = inputs.get(x),
                Some(p) if p == *x => {}
                Some(_) => return Ok(None),
            }
            let Some(y_msg) = inputs.get(y) else { return Ok(None) };
            a_seq.push(graph.state(*a).clone());
            y_seq.push(y_msg.clone());
            prev = Some(step.out);
        }
        let Some(prior) = prior else { return Ok(None) };
        // the artifact bakes ONE isotropic observation covariance; any
        // other noise shape must take the per-step path
        let sigma2 = y_seq[0].cov[(0, 0)].re;
        let n = prior.dim();
        for y in &y_seq {
            if y.cov.dist(&CMatrix::scaled_identity(n, sigma2)) > 1e-12 {
                return Ok(None);
            }
        }
        let sigma2 = sigma2 as f32;
        let chain = self.rt.rls_chain(prior, &a_seq, &y_seq, sigma2)?;
        let final_msg = chain.last().context("empty fused chain result")?.clone();
        let outputs = collect_outputs(schedule, |_| Some(final_msg.clone()))?;
        Ok(Some(Execution { outputs, stats: RunStats::default() }))
    }
}

impl Engine for XlaEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Xla
    }

    /// A pure compound-node stream chunks to the AOT `rls_chain`
    /// artifact's baked section count so every full chunk goes out as
    /// ONE fused dispatch ([`Session::run_stream`] pads tail chunks with
    /// `A = 0` identity sections). Without the artifact — or when the
    /// workload's binding is state-dependent (`app_max == 1`) — the
    /// stream runs sample-at-a-time.
    fn stream_chunk(&self, app_max: usize) -> usize {
        match self.rt.manifest.entry("rls_chain").and_then(|e| e.leading_dim()) {
            Some(s) if s > 1 && app_max >= s => s,
            _ => 1,
        }
    }

    fn execute(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        _program: Option<&Arc<CompiledProgram>>,
        inputs: &HashMap<MsgId, GaussMessage>,
    ) -> Result<Execution> {
        if let Some(exec) = self.try_fused_chain(graph, schedule, inputs)? {
            return Ok(exec);
        }
        let mut env: HashMap<MsgId, GaussMessage> = inputs.clone();
        for step in &schedule.steps {
            let out = {
                let get = |id: &MsgId| {
                    env.get(id)
                        .with_context(|| format!("step uses unbound message {}", id.0))
                };
                match &step.op {
                    StepOp::CompoundObservation { x, y, a } => {
                        self.rt.cn_update(get(x)?, get(y)?, graph.state(*a))?
                    }
                    StepOp::Multiply { x, a } => nodes::multiply(get(x)?, graph.state(*a)),
                    StepOp::Add { x, y } => nodes::add(get(x)?, get(y)?),
                    StepOp::Equality { x, y } => nodes::equality(get(x)?, get(y)?)?,
                    StepOp::CompoundEquality { x, y, a } => {
                        let (wx, wxm) = get(x)?
                            .to_weight_form()
                            .context("V_X singular in weight conversion")?;
                        let (wy, wym) = get(y)?
                            .to_weight_form()
                            .context("V_Y singular in weight conversion")?;
                        let (wz, wzm) = nodes::compound_equality_weight(
                            &wx,
                            &wxm,
                            &wy,
                            &wym,
                            graph.state(*a),
                        );
                        GaussMessage::from_weight_form(&wz, &wzm)
                            .context("W_Z singular after compound equality")?
                    }
                }
            };
            env.insert(step.out, out);
        }
        let outputs = collect_outputs(schedule, |mid| env.get(mid).cloned())?;
        Ok(Execution { outputs, stats: RunStats::default() })
    }
}

/// Gather the schedule's output messages through a per-id lookup.
fn collect_outputs(
    schedule: &Schedule,
    mut lookup: impl FnMut(&MsgId) -> Option<GaussMessage>,
) -> Result<Vec<(MsgId, crate::gmp::EdgeId, GaussMessage)>> {
    schedule
        .outputs
        .iter()
        .map(|(mid, eid)| {
            lookup(mid)
                .map(|m| (*mid, *eid, m))
                .with_context(|| format!("engine produced no message for output {}", mid.0))
        })
        .collect()
}

// ---------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------

/// Program-cache counters (observability for the serving layer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Programs served from cache.
    pub hits: u64,
    /// Programs compiled because no cached entry matched.
    pub misses: u64,
    /// Distinct compiled programs resident.
    pub programs: usize,
}

/// Result of [`Session::run`]: the typed outcome plus everything the
/// serving/benchmark layers report.
#[derive(Clone, Debug)]
pub struct RunReport<O> {
    /// The workload's typed outcome.
    pub outcome: O,
    /// The workload's scalar quality metric (lower is better).
    pub quality: f64,
    /// Simulated device cycles (0 on engines without a cycle model).
    pub cycles: u64,
    /// Sections (store handshakes) the device committed.
    pub sections: u64,
    /// Simulated cycles per committed section.
    pub cycles_per_section: u64,
    /// Compile statistics when a program was compiled or fetched.
    pub compile_stats: Option<CompileStats>,
    /// Engine that executed the run.
    pub engine: EngineKind,
    /// True when the compiled program came from the session cache.
    pub cached: bool,
}

/// Low-level result of [`Session::dispatch`] (the serving layer routes
/// raw models through this without the [`Workload`] trait).
#[derive(Clone, Debug)]
pub struct Dispatch {
    /// Raw execution result (outputs + device stats).
    pub exec: Execution,
    /// Compile statistics when a program was compiled or fetched.
    pub compile_stats: Option<CompileStats>,
    /// True when the program came from the session cache.
    pub cached: bool,
}

/// Default upper bound on resident compiled programs per session. The
/// serving layer forwards arbitrary client workload shapes into the
/// cache, so it must not grow without bound; on overflow the
/// **least-recently-used** entry is evicted (a shape seen again later
/// simply recompiles). A hot serving shape that fires on every request
/// therefore survives any number of one-off shapes passing through.
const DEFAULT_CACHE_CAPACITY: usize = 128;

/// One engine + one program cache = the crate's execution surface.
pub struct Session {
    engine: Box<dyn Engine>,
    cache: HashMap<String, Arc<CompiledProgram>>,
    /// Cache keys from least- to most-recently used (LRU eviction:
    /// hits and re-inserts move a key to the back, overflow pops the
    /// front). Linear scans are fine at ≤ `cache_capacity` entries.
    cache_order: Vec<String>,
    cache_capacity: usize,
    hits: u64,
    misses: u64,
    /// Deployment telemetry handle ([`Session::set_telemetry`]); absent
    /// on standalone sessions, which then skip every obs hook.
    telemetry: Option<Arc<Telemetry>>,
    /// Parent span for the next dispatch ([`Session::set_trace_context`]).
    trace: Option<TraceContext>,
    /// Registry counters resolved once at [`Session::set_telemetry`]
    /// so the dispatch hot path never touches the registry maps.
    ctr_cache_hit: Option<Arc<AtomicU64>>,
    ctr_cache_miss: Option<Arc<AtomicU64>>,
}

impl Session {
    /// A session over an explicit engine.
    pub fn new(engine: Box<dyn Engine>) -> Self {
        Session {
            engine,
            cache: HashMap::new(),
            cache_order: Vec::new(),
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            hits: 0,
            misses: 0,
            telemetry: None,
            trace: None,
            ctr_cache_hit: None,
            ctr_cache_miss: None,
        }
    }

    /// Attach the deployment's shared [`Telemetry`] handle: dispatches
    /// feed the `engine.cache_hit`/`engine.cache_miss` registry
    /// counters, and (when spans are enabled *and* a trace context is
    /// set) record `engine.*` spans with the device's per-opcode phases
    /// as children.
    pub fn set_telemetry(&mut self, tel: Arc<Telemetry>) {
        self.ctr_cache_hit = Some(tel.registry().counter("engine.cache_hit"));
        self.ctr_cache_miss = Some(tel.registry().counter("engine.cache_miss"));
        self.telemetry = Some(tel);
    }

    /// Set (or clear) the parent span the next dispatch should attach
    /// its spans under — the farm device loop calls this per message
    /// with the context carried over the wire.
    pub fn set_trace_context(&mut self, ctx: Option<TraceContext>) {
        self.trace = ctx;
    }

    /// Bound the compiled-program cache (deployment tuning and eviction
    /// tests). Shrinking below the resident count evicts LRU-first.
    pub fn set_cache_capacity(&mut self, capacity: usize) {
        self.cache_capacity = capacity.max(1);
        while self.cache_order.len() > self.cache_capacity {
            let evicted = self.cache_order.remove(0);
            self.cache.remove(&evicted);
        }
    }

    /// f64 golden reference session.
    pub fn golden() -> Self {
        Session::new(Box::new(GoldenEngine::default()))
    }

    /// Cycle-accurate simulator session.
    pub fn fgp_sim(config: FgpConfig) -> Self {
        Session::new(Box::new(FgpSimEngine::new(config)))
    }

    /// PJRT/XLA session.
    pub fn xla(rt: RuntimeClient) -> Self {
        Session::new(Box::new(XlaEngine::new(rt)))
    }

    /// Session for a declared [`Precision`]: `F64` routes to the golden
    /// reference rules, `Fixed(fmt)` to the quantized datapath (the
    /// cycle-accurate simulator at that Q-format).
    pub fn with_precision(p: super::precision::Precision) -> Self {
        match p {
            super::precision::Precision::F64 => Session::golden(),
            super::precision::Precision::Fixed(fmt) => {
                Session::fgp_sim(FgpConfig { fmt, ..FgpConfig::default() })
            }
        }
    }

    /// The arithmetic precision this session computes in.
    pub fn precision(&self) -> super::precision::Precision {
        self.engine.precision()
    }

    /// Switch the engine's fixed-point format. Returns `true` when the
    /// engine honours the request (see [`Engine::set_fixed_format`]);
    /// the program cache survives the switch — the structural signature
    /// has no format component, only the device state is rebuilt.
    pub fn set_fixed_format(&mut self, fmt: QFormat) -> bool {
        self.engine.set_fixed_format(fmt)
    }

    /// Which engine this session drives.
    pub fn engine_kind(&self) -> EngineKind {
        self.engine.kind()
    }

    /// Device dimension, when the engine has one.
    pub fn device_n(&self) -> Option<usize> {
        self.engine.device_n()
    }

    /// Program-cache counters (hits, misses, resident programs).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses, programs: self.cache.len() }
    }

    /// Run a workload end to end.
    pub fn run<W: Workload + ?Sized>(&mut self, w: &W) -> Result<RunReport<W::Outcome>> {
        if let Some(dn) = self.engine.device_n() {
            if w.n() != dn {
                bail!(
                    "workload '{}' has n={} but the device is configured for n={}",
                    w.name(),
                    w.n(),
                    dn
                );
            }
        }
        let (graph, schedule) = w.model()?;
        let opts = w.compile_options();
        let inputs = w.inputs(&graph, &schedule)?;
        let d = self
            .dispatch(&graph, &schedule, &inputs, &opts)
            .with_context(|| format!("running workload '{}'", w.name()))?;
        let outcome = w.outcome(&d.exec)?;
        let quality = w.quality(&outcome);
        Ok(RunReport {
            outcome,
            quality,
            cycles: d.exec.stats.cycles,
            sections: d.exec.stats.sections,
            cycles_per_section: d.exec.stats.cycles / d.exec.stats.sections.max(1),
            compile_stats: d.compile_stats,
            engine: self.engine.kind(),
            cached: d.cached,
        })
    }

    /// Run a [`StreamingWorkload`] to the end of its sample stream —
    /// the paper's §VI steady-state serving shape.
    ///
    /// The steady-state model is compiled **once** (program engines);
    /// every subsequent chunk of samples reuses the resident program and
    /// only re-stages data: on the simulator the chunk rides one
    /// `run_program` call with the host refilling the shared memmap
    /// slots at each store handshake, and on the XLA engine a pure
    /// compound-node stream dispatches full chunks through the AOT chain
    /// artifact with `A = 0` identity sections padding the tail. A tail
    /// shorter than the chunk on the simulator compiles one extra
    /// (cached) tail program so its cycle accounting stays honest.
    pub fn run_stream<W: StreamingWorkload + ?Sized>(
        &mut self,
        w: &W,
    ) -> Result<StreamReport<W::StreamOutcome>> {
        self.run_stream_inner(w, w.initial_state(), 0, Vec::new())
    }

    /// Resume a [`StreamingWorkload`] from a [`StreamCheckpoint`] — the
    /// failover half of the serve tier's checkpoint/restore contract.
    ///
    /// Sample numbering continues at `ckpt.samples` (the workload's
    /// `next_sample(k, ..)` is asked for exactly the samples an
    /// uninterrupted run had still ahead of it), so the report's
    /// `samples` and the outcome cover the **whole** stream while
    /// `chunks`/`cycles`/`compiles` count only the post-resume work this
    /// session actually performed. By chunk invariance (see
    /// [`StreamCheckpoint`]) the final state is bitwise identical to an
    /// uninterrupted [`Session::run_stream`] on the same engine even
    /// though the resume point re-partitions the chunks.
    pub fn run_stream_from<W: StreamingWorkload + ?Sized>(
        &mut self,
        w: &W,
        ckpt: &StreamCheckpoint,
    ) -> Result<StreamReport<W::StreamOutcome>> {
        if ckpt.stream_name != w.stream_name() {
            bail!(
                "checkpoint belongs to stream '{}' but the workload is '{}'",
                ckpt.stream_name,
                w.stream_name()
            );
        }
        self.run_stream_inner(w, ckpt.state.clone(), ckpt.samples, ckpt.boundaries.clone())
    }

    fn run_stream_inner<W: StreamingWorkload + ?Sized>(
        &mut self,
        w: &W,
        state0: GaussMessage,
        samples0: u64,
        boundaries0: Vec<GaussMessage>,
    ) -> Result<StreamReport<W::StreamOutcome>> {
        if let Some(dn) = self.engine.device_n() {
            if w.state_dim() != dn {
                bail!(
                    "stream '{}' has n={} but the device is configured for n={}",
                    w.stream_name(),
                    w.state_dim(),
                    dn
                );
            }
        }
        let opts = w.stream_compile_options();
        let chunk = self.engine.stream_chunk(w.max_chunk().max(1)).max(1);
        let mut main = StreamBinder::build(w, chunk)
            .with_context(|| format!("building stream '{}' chunk model", w.stream_name()))?;
        let mut main_program: Option<Arc<CompiledProgram>> = None;
        // XLA tails pad to the chunk instead of recompiling: the padded
        // sections are exact identity updates (see StreamBinder::paddable)
        let pad_tails = self.engine.kind() == EngineKind::Xla && main.paddable();

        let mut state = state0;
        let mut boundaries: Vec<GaussMessage> = boundaries0;
        let mut samples: u64 = samples0;
        let mut chunks: u64 = 0;
        let mut cycles: u64 = 0;
        let mut sections: u64 = 0;
        let mut compiles: u64 = 0;
        let mut cache_hits: u64 = 0;

        loop {
            let mut batch: Vec<StreamSample> = Vec::with_capacity(chunk);
            while batch.len() < chunk {
                match w.next_sample(samples as usize + batch.len(), &state)? {
                    Some(s) => batch.push(s),
                    None => break,
                }
            }
            let real = batch.len();
            if real == 0 {
                break;
            }
            let exec = if real == chunk || pad_tails {
                if real < chunk {
                    let pad = main.pad_sample(batch.last().expect("non-empty batch"));
                    while batch.len() < chunk {
                        batch.push(pad.clone());
                    }
                }
                if self.engine.needs_program() && main_program.is_none() {
                    let (p, cached) = self.lookup_or_compile(&main.graph, &main.schedule, &opts)?;
                    if cached {
                        cache_hits += 1;
                    } else {
                        compiles += 1;
                    }
                    main_program = Some(p);
                }
                main.bind(&state, &batch)?;
                self.engine
                    .execute(&main.graph, &main.schedule, main_program.as_ref(), &main.inputs)
                    .with_context(|| format!("stream '{}' chunk {chunks}", w.stream_name()))?
            } else {
                // short tail: a one-off model of exactly `real` samples
                let mut tail = StreamBinder::build(w, real)
                    .with_context(|| format!("building stream '{}' tail model", w.stream_name()))?;
                let tail_program = if self.engine.needs_program() {
                    let (p, cached) = self.lookup_or_compile(&tail.graph, &tail.schedule, &opts)?;
                    if cached {
                        cache_hits += 1;
                    } else {
                        compiles += 1;
                    }
                    Some(p)
                } else {
                    None
                };
                tail.bind(&state, &batch)?;
                self.engine
                    .execute(&tail.graph, &tail.schedule, tail_program.as_ref(), &tail.inputs)
                    .with_context(|| format!("stream '{}' tail chunk", w.stream_name()))?
            };
            state = exec.output()?.clone();
            boundaries.push(state.clone());
            cycles += exec.stats.cycles;
            sections += exec.stats.sections;
            samples += real as u64;
            chunks += 1;
            if real < chunk {
                break; // the stream ended inside this chunk
            }
        }

        let run = StreamRun { final_state: state, boundaries, samples };
        let outcome = w.stream_outcome(&run)?;
        Ok(StreamReport {
            outcome,
            final_state: run.final_state,
            samples,
            chunks,
            chunk,
            cycles,
            sections,
            compiles,
            cache_hits,
            engine: self.engine.kind(),
        })
    }

    /// Execute a raw model (graph + schedule + bound inputs) — the entry
    /// point the coordinator routes [`WorkloadRequest`]s through.
    ///
    /// [`WorkloadRequest`]: crate::coordinator::backend::WorkloadRequest
    pub fn dispatch(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        inputs: &HashMap<MsgId, GaussMessage>,
        opts: &CompileOptions,
    ) -> Result<Dispatch> {
        if let Some(dn) = self.engine.device_n() {
            if let Some(e) = graph.edges.iter().find(|e| e.dim != dn) {
                bail!(
                    "graph edge '{}' has dim {} but the device is configured for n={}",
                    e.label,
                    e.dim,
                    dn
                );
            }
        }
        for (mid, eid) in &schedule.inputs {
            if !inputs.contains_key(mid) {
                bail!("no input bound for edge '{}'", graph.edges[eid.0].label);
            }
        }
        let (program, compile_stats, cached) = if self.engine.needs_program() {
            let t0 = match (&self.telemetry, self.trace) {
                (Some(tel), Some(_)) if tel.enabled() => tel.now_ns(),
                _ => 0,
            };
            let (p, cached) = self.lookup_or_compile(graph, schedule, opts)?;
            if let Some(ctr) = if cached { &self.ctr_cache_hit } else { &self.ctr_cache_miss } {
                ctr.fetch_add(1, Ordering::Relaxed);
            }
            if let (Some(tel), Some(ctx)) = (&self.telemetry, self.trace) {
                if tel.enabled() {
                    let name = if cached { "engine.cache_hit" } else { "engine.compile" };
                    let instrs = p.stats.instrs_compressed as u64;
                    tel.span(ctx.child(), ctx.span_id, name, "engine", t0, instrs);
                }
            }
            let stats = p.stats;
            (Some(p), Some(stats), cached)
        } else {
            (None, None, false)
        };
        // Hand the engine a child context for the duration of this
        // dispatch only; cleared afterwards so a later untraced dispatch
        // can't attach spans to a stale request.
        let exec_ctx = match (&self.telemetry, self.trace) {
            (Some(tel), Some(ctx)) if tel.enabled() => {
                let child = ctx.child();
                self.engine.set_trace(Some((Arc::clone(tel), child)));
                Some((child, ctx.span_id, tel.now_ns()))
            }
            _ => {
                self.engine.set_trace(None);
                None
            }
        };
        let exec = self.engine.execute(graph, schedule, program.as_ref(), inputs);
        if exec_ctx.is_some() {
            self.engine.set_trace(None);
        }
        let exec = exec?;
        if let (Some(tel), Some((child, parent, t0))) = (&self.telemetry, exec_ctx) {
            tel.span(child, parent, "engine.execute", "engine", t0, exec.stats.cycles);
        }
        Ok(Dispatch { exec, compile_stats, cached })
    }

    /// Compile (or fetch) the program for a model without executing it.
    pub fn precompile(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        opts: &CompileOptions,
    ) -> Result<Arc<CompiledProgram>> {
        self.lookup_or_compile(graph, schedule, opts).map(|(p, _)| p)
    }

    /// Pre-seed the cache with an externally compiled program (farms
    /// compile once on the control plane and install on every device).
    pub fn install(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        opts: &CompileOptions,
        program: Arc<CompiledProgram>,
    ) {
        let key = program_key(graph, schedule, opts);
        self.insert_program(key, program);
    }

    /// Move `key` to the most-recently-used end of the order list.
    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.cache_order.iter().position(|k| k == key) {
            let k = self.cache_order.remove(pos);
            self.cache_order.push(k);
        }
    }

    fn insert_program(&mut self, key: String, program: Arc<CompiledProgram>) {
        if self.cache.insert(key.clone(), program).is_some() {
            // re-install of a resident shape counts as a use
            self.touch(&key);
            return;
        }
        while self.cache_order.len() >= self.cache_capacity {
            let evicted = self.cache_order.remove(0);
            self.cache.remove(&evicted);
        }
        self.cache_order.push(key);
    }

    fn lookup_or_compile(
        &mut self,
        graph: &FactorGraph,
        schedule: &Schedule,
        opts: &CompileOptions,
    ) -> Result<(Arc<CompiledProgram>, bool)> {
        let key = program_key(graph, schedule, opts);
        if let Some(p) = self.cache.get(&key) {
            let p = Arc::clone(p);
            self.hits += 1;
            self.touch(&key);
            return Ok((p, true));
        }
        let compiled = Arc::new(compile(graph, schedule, opts)?);
        self.misses += 1;
        self.insert_program(key, Arc::clone(&compiled));
        Ok((compiled, false))
    }
}

/// Structural signature of a model + compile options: everything that
/// determines the compiled program, nothing that is data.
fn program_key(graph: &FactorGraph, schedule: &Schedule, opts: &CompileOptions) -> String {
    use std::fmt::Write;
    let mut k = String::with_capacity(64 + 8 * graph.edges.len() + 12 * graph.nodes.len());
    let _ = write!(
        k,
        "o{},{},{},{},{},{},{:?},{};",
        opts.program_id,
        opts.optimize_memory as u8,
        opts.compress_loops as u8,
        opts.pm_capacity,
        opts.state_capacity,
        opts.alloc.optimize as u8,
        opts.alloc.policy,
        opts.alloc.capacity,
    );
    for e in &graph.edges {
        let _ = write!(
            k,
            "e{},{}{}{:?};",
            e.dim,
            e.is_input as u8,
            e.is_output as u8,
            e.stream_group
        );
    }
    for g in &graph.state_stream_groups {
        let _ = write!(k, "g{:?};", g);
    }
    for node in &graph.nodes {
        let _ = match &node.kind {
            NodeKind::Equality => write!(k, "q"),
            NodeKind::Add => write!(k, "a"),
            NodeKind::Multiply { a } => write!(k, "m{}", a.0),
            NodeKind::CompoundObservation { a } => write!(k, "c{}", a.0),
            NodeKind::CompoundEquality { a } => write!(k, "w{}", a.0),
        };
        for e in &node.inputs {
            let _ = write!(k, ",{}", e.0);
        }
        let _ = write!(k, ">{};", node.output.0);
    }
    // the schedule is almost always the forward sweep of the graph, but
    // Session::dispatch accepts caller-built schedules too — encode the
    // step ops and their order so a reordered schedule is a different key
    let _ = write!(k, "s{}", schedule.steps.len());
    for step in &schedule.steps {
        let _ = match &step.op {
            StepOp::Equality { x, y } => write!(k, "E{},{}", x.0, y.0),
            StepOp::Add { x, y } => write!(k, "A{},{}", x.0, y.0),
            StepOp::Multiply { x, a } => write!(k, "M{},{}", x.0, a.0),
            StepOp::CompoundObservation { x, y, a } => write!(k, "C{},{},{}", x.0, y.0, a.0),
            StepOp::CompoundEquality { x, y, a } => write!(k, "W{},{},{}", x.0, y.0, a.0),
        };
        let _ = write!(k, ">{};", step.out.0);
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::workload::{bind_streamed, preload_id};
    use crate::gmp::matrix::c64;
    use crate::testutil::Rng;

    /// The smallest workload: one compound-observation section.
    struct MiniCn {
        x: GaussMessage,
        y: GaussMessage,
        a: CMatrix,
    }

    impl Workload for MiniCn {
        type Outcome = GaussMessage;

        fn name(&self) -> &str {
            "mini-cn"
        }

        fn n(&self) -> usize {
            self.x.dim()
        }

        fn model(&self) -> Result<(FactorGraph, Schedule)> {
            let mut g = FactorGraph::new();
            g.rls_chain(self.n(), std::slice::from_ref(&self.a));
            let s = Schedule::forward_sweep(&g);
            Ok((g, s))
        }

        fn inputs(
            &self,
            graph: &FactorGraph,
            schedule: &Schedule,
        ) -> Result<HashMap<MsgId, GaussMessage>> {
            let mut map = HashMap::new();
            map.insert(preload_id(graph, schedule, "msg_prior")?, self.x.clone());
            bind_streamed(graph, schedule, std::slice::from_ref(&self.y), &mut map)?;
            Ok(map)
        }

        fn outcome(&self, exec: &Execution) -> Result<GaussMessage> {
            exec.output().cloned()
        }

        fn quality(&self, outcome: &GaussMessage) -> f64 {
            outcome.trace_cov()
        }

        fn tolerance(&self) -> f64 {
            0.05
        }
    }

    fn mini(rng: &mut Rng) -> MiniCn {
        let n = 4;
        MiniCn {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn golden_session_matches_node_rule() {
        let mut rng = Rng::new(1);
        let w = mini(&mut rng);
        let mut s = Session::golden();
        let report = s.run(&w).unwrap();
        let want = nodes::compound_observation(&w.x, &w.y, &w.a, false).unwrap();
        assert!(report.outcome.dist(&want) < 1e-9);
        assert_eq!(report.engine, EngineKind::Golden);
        // golden never touches the program cache
        assert_eq!(s.cache_stats(), CacheStats::default());
    }

    #[test]
    fn fgp_session_tracks_golden_and_caches() {
        let mut rng = Rng::new(2);
        let mut golden = Session::golden();
        let mut sim = Session::fgp_sim(FgpConfig::default());
        for i in 0..4 {
            let w = mini(&mut rng);
            let g = golden.run(&w).unwrap();
            let f = sim.run(&w).unwrap();
            assert!(f.outcome.dist(&g.outcome) < 0.05, "iter {i}");
            assert_eq!(f.cached, i > 0, "iter {i}");
            assert!(f.cycles > 0);
        }
        let stats = sim.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.programs), (3, 1, 1));
    }

    #[test]
    fn size_mismatch_is_an_error_not_a_panic() {
        let mut rng = Rng::new(3);
        let n = 6;
        let w = MiniCn {
            x: GaussMessage::isotropic(n, 0.2),
            y: GaussMessage::isotropic(n, 0.2),
            a: CMatrix::random(&mut rng, n, n).scale(0.2),
        };
        let mut sim = Session::fgp_sim(FgpConfig::default()); // n = 4
        let err = sim.run(&w).unwrap_err();
        assert!(format!("{err:#}").contains("n=6"), "{err:#}");
    }

    #[test]
    fn lru_evicts_least_recently_used_not_oldest_inserted() {
        let shape = |sections: usize, seed: u64| {
            let mut rng = Rng::new(seed);
            let mut g = FactorGraph::new();
            let a_list: Vec<CMatrix> =
                (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
            g.rls_chain(4, &a_list);
            let s = Schedule::forward_sweep(&g);
            (g, s)
        };
        let opts = CompileOptions::default();
        let mut s = Session::fgp_sim(FgpConfig::default());
        s.set_cache_capacity(2);
        let (ga, sa) = shape(1, 1);
        let (gb, sb) = shape(2, 2);
        let (gc, sc) = shape(3, 3);
        s.precompile(&ga, &sa, &opts).unwrap(); // miss: [A]
        s.precompile(&gb, &sb, &opts).unwrap(); // miss: [A, B]
        s.precompile(&ga, &sa, &opts).unwrap(); // hit:  [B, A]
        // under FIFO the next insert would evict A (oldest inserted);
        // under LRU it must evict B (least recently used)
        s.precompile(&gc, &sc, &opts).unwrap(); // miss: [A, C]
        s.precompile(&ga, &sa, &opts).unwrap(); // must still be a hit
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.programs), (2, 3, 2), "{stats:?}");
        s.precompile(&gb, &sb, &opts).unwrap(); // B was evicted: miss again
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.programs), (2, 4, 2), "{stats:?}");
    }

    #[test]
    fn shrinking_capacity_evicts_lru_first() {
        let shape = |sections: usize| {
            let mut rng = Rng::new(sections as u64);
            let mut g = FactorGraph::new();
            let a_list: Vec<CMatrix> =
                (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
            g.rls_chain(4, &a_list);
            let s = Schedule::forward_sweep(&g);
            (g, s)
        };
        let opts = CompileOptions::default();
        let mut s = Session::fgp_sim(FgpConfig::default());
        let (g1, s1) = shape(1);
        let (g2, s2) = shape(2);
        let (g3, s3) = shape(3);
        s.precompile(&g1, &s1, &opts).unwrap();
        s.precompile(&g2, &s2, &opts).unwrap();
        s.precompile(&g3, &s3, &opts).unwrap();
        s.precompile(&g1, &s1, &opts).unwrap(); // [2, 3, 1] by recency
        s.set_cache_capacity(1);
        assert_eq!(s.cache_stats().programs, 1);
        s.precompile(&g1, &s1, &opts).unwrap(); // the survivor is the MRU
        assert_eq!(s.cache_stats().hits, 2);
    }

    #[test]
    fn malformed_schedule_surfaces_typed_error_through_dispatch() {
        use crate::gmp::ScheduleError;
        let mut rng = Rng::new(5);
        let w = mini(&mut rng);
        let (graph, mut schedule) = w.model().unwrap();
        let inputs = w.inputs(&graph, &schedule).unwrap();
        // corrupt the schedule: the step now consumes a message id that
        // nothing defines (caller-built schedules reach dispatch raw)
        if let StepOp::CompoundObservation { x, .. } = &mut schedule.steps[0].op {
            *x = MsgId(99);
        }
        let err = Session::golden()
            .dispatch(&graph, &schedule, &inputs, &CompileOptions::default())
            .unwrap_err();
        let sched_err = err
            .downcast_ref::<ScheduleError>()
            .unwrap_or_else(|| panic!("want ScheduleError in the chain, got {err:#}"));
        assert_eq!(*sched_err, ScheduleError::UndefinedMessage { step: 0, msg: 99 });
    }

    #[test]
    fn program_key_separates_shapes_and_options() {
        let mut rng = Rng::new(4);
        let shape = |sections: usize| {
            let mut g = FactorGraph::new();
            let a_list: Vec<CMatrix> =
                (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
            g.rls_chain(4, &a_list);
            let s = Schedule::forward_sweep(&g);
            (g, s)
        };
        let (g2, s2) = shape(2);
        let (g2b, s2b) = shape(2);
        let (g3, s3) = shape(3);
        let opts = CompileOptions::default();
        // same shape, different data -> same key
        assert_eq!(program_key(&g2, &s2, &opts), program_key(&g2b, &s2b, &opts));
        assert_ne!(program_key(&g2, &s2, &opts), program_key(&g3, &s3, &opts));
        let flat = CompileOptions { compress_loops: false, ..Default::default() };
        assert_ne!(program_key(&g2, &s2, &opts), program_key(&g2, &s2, &flat));
    }

    /// A streaming workload truncated to its first `limit` samples —
    /// the prefix half of the checkpoint/resume conformance test.
    struct Truncated<'a> {
        inner: &'a crate::apps::rls::RlsProblem,
        limit: usize,
    }

    impl StreamingWorkload for Truncated<'_> {
        type StreamOutcome = StreamRun;

        fn stream_name(&self) -> &str {
            self.inner.stream_name()
        }

        fn state_dim(&self) -> usize {
            self.inner.state_dim()
        }

        fn stream_model(&self, chunk: usize) -> Result<(FactorGraph, Schedule)> {
            self.inner.stream_model(chunk)
        }

        fn initial_state(&self) -> GaussMessage {
            self.inner.initial_state()
        }

        fn next_sample(
            &self,
            k: usize,
            state: &GaussMessage,
        ) -> Result<Option<StreamSample>> {
            if k >= self.limit {
                return Ok(None);
            }
            self.inner.next_sample(k, state)
        }

        fn stream_outcome(&self, run: &StreamRun) -> Result<StreamRun> {
            Ok(run.clone())
        }
    }

    /// Bitwise equality of two messages (f64-exact; NOT a closeness test).
    fn assert_bitwise(a: &GaussMessage, b: &GaussMessage) {
        assert_eq!(a, b, "states differ bitwise");
    }

    #[test]
    fn run_stream_from_resumes_bitwise_identically() {
        let p = crate::apps::rls::RlsProblem::synthetic(4, 16, 0.01, 77);
        for mk in [Session::golden as fn() -> Session, || Session::fgp_sim(FgpConfig::default())]
        {
            // uninterrupted reference
            let full = mk().run_stream(&p).unwrap();
            // run the first 8 samples, checkpoint, resume the rest in a
            // *fresh* session (different chunk partitioning post-resume)
            let half = mk().run_stream(&Truncated { inner: &p, limit: 8 }).unwrap();
            let ckpt = StreamCheckpoint {
                stream_name: p.stream_name().to_string(),
                samples: half.samples,
                state: half.final_state.clone(),
                boundaries: Vec::new(),
            };
            let resumed = mk().run_stream_from(&p, &ckpt).unwrap();
            assert_eq!(resumed.samples, 16);
            assert_bitwise(&resumed.final_state, &full.final_state);
        }
    }

    #[test]
    fn with_precision_routes_engines_and_reports_width() {
        use super::super::precision::Precision;
        let s = Session::with_precision(Precision::F64);
        assert_eq!(s.engine_kind(), EngineKind::Golden);
        assert_eq!(s.precision(), Precision::F64);

        let s = Session::with_precision(Precision::fixed_default());
        assert_eq!(s.engine_kind(), EngineKind::FgpSim);
        assert_eq!(s.precision(), Precision::Fixed(QFormat::q5_10()));
        assert_eq!(s.precision().width_bits(), 16);

        // the f64 reference refuses a fixed format instead of silently
        // computing at a different width
        let mut golden = Session::golden();
        assert!(!golden.set_fixed_format(QFormat::q5_10()));
        assert_eq!(golden.precision(), Precision::F64);
    }

    #[test]
    fn format_switch_rebuilds_device_but_keeps_program_cache() {
        use super::super::precision::Precision;
        let mut rng = Rng::new(9);
        let w = mini(&mut rng);
        let mut s = Session::fgp_sim(FgpConfig::default());
        let narrow = s.run(&w).unwrap();
        assert!(!narrow.cached);

        // widen: the structural signature has no format component, so
        // the compiled program is a cache hit — only the device rebuilds
        assert!(s.set_fixed_format(QFormat::new(8, 20)));
        assert_eq!(s.precision(), Precision::Fixed(QFormat::new(8, 20)));
        let wide = s.run(&w).unwrap();
        assert!(wide.cached, "format switch must not invalidate the program cache");
        assert!(
            narrow.outcome.dist(&wide.outcome) > 0.0,
            "q5.10 and q8.20 must quantize differently"
        );

        // switching back reproduces the original run bitwise
        assert!(s.set_fixed_format(QFormat::q5_10()));
        let again = s.run(&w).unwrap();
        assert!(again.cached);
        assert_bitwise(&again.outcome, &narrow.outcome);
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.programs), (2, 1, 1));
    }

    #[test]
    fn run_stream_from_rejects_foreign_checkpoint() {
        let p = crate::apps::rls::RlsProblem::synthetic(4, 8, 0.01, 5);
        let ckpt = StreamCheckpoint {
            stream_name: "kalman_track".to_string(),
            samples: 0,
            state: p.initial_state(),
            boundaries: Vec::new(),
        };
        let err = Session::golden().run_stream_from(&p, &ckpt).unwrap_err();
        assert!(err.to_string().contains("belongs to stream"), "{err:#}");
    }
}
