//! The [`Workload`] trait: one description, every engine.
//!
//! A workload is the ForneyLab-style triple *model → data → outcome*:
//! build a factor graph and schedule, bind host-side messages to the
//! graph's input edges, and turn the raw execution result back into a
//! typed, scoreable outcome. Engines never see application types and
//! applications never see engine types; [`super::Session`] is the only
//! meeting point.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::compiler::CompileOptions;
use crate::fgp::RunStats;
use crate::gmp::message::GaussMessage;
use crate::gmp::{EdgeId, FactorGraph, MsgId, Schedule};

/// Raw result of executing a workload's model on some engine: the
/// messages on the graph's output edges plus device statistics (zero on
/// engines that do not model cycles).
#[derive(Clone, Debug)]
pub struct Execution {
    /// Output messages in schedule order: (virtual id, edge, message).
    pub outputs: Vec<(MsgId, EdgeId, GaussMessage)>,
    /// Device statistics (simulator runs only; zeros elsewhere).
    pub stats: RunStats,
}

impl Execution {
    /// The sole output message (errors if the graph has several or none).
    pub fn output(&self) -> Result<&GaussMessage> {
        match self.outputs.as_slice() {
            [(_, _, msg)] => Ok(msg),
            other => bail!("expected exactly one output edge, graph has {}", other.len()),
        }
    }

    /// Output message on a specific edge.
    pub fn output_at(&self, edge: EdgeId) -> Option<&GaussMessage> {
        self.outputs.iter().find(|(_, e, _)| *e == edge).map(|(_, _, m)| m)
    }
}

/// An application workload expressed as a factor-graph model plus data.
///
/// The contract every engine relies on:
///
/// 1. [`model`](Workload::model) builds the graph and schedule. Streamed
///    inputs (edges/states in a stream group) are refilled per section by
///    the engine from the same bindings, so long chains fit the device's
///    64-kbit message memory.
/// 2. [`inputs`](Workload::inputs) binds a message to **every** input
///    edge of the schedule (preloaded and streamed alike, keyed by
///    virtual message id). State matrices ride in the graph itself.
/// 3. [`outcome`](Workload::outcome) interprets the output messages;
///    [`quality`](Workload::quality) reduces an outcome to one
///    lower-is-better number that [`tolerance`](Workload::tolerance)
///    bounds across engines (the cross-engine conformance contract).
pub trait Workload {
    /// Typed result of one run.
    type Outcome;

    /// Short identifier (diagnostics, cache reports).
    fn name(&self) -> &str;

    /// Problem/state dimension (must match the device size).
    fn n(&self) -> usize;

    /// Build the factor graph and its message-update schedule.
    fn model(&self) -> Result<(FactorGraph, Schedule)>;

    /// Bind a message to every input edge of the schedule.
    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>>;

    /// Interpret the execution result.
    fn outcome(&self, exec: &Execution) -> Result<Self::Outcome>;

    /// Scalar quality metric, lower is better (e.g. relative MSE).
    fn quality(&self, outcome: &Self::Outcome) -> f64;

    /// Documented cross-engine slack: on any engine the quality must stay
    /// within `golden_quality + tolerance()`.
    fn tolerance(&self) -> f64;

    /// Compiler options for program-based engines.
    fn compile_options(&self) -> CompileOptions {
        CompileOptions::default()
    }
}

/// Split a schedule's input bindings into preloaded and streamed edges,
/// the streamed half sorted into section order (virtual ids are assigned
/// in graph-construction order, which is section order for every builder
/// in this crate). Most [`Workload::inputs`] implementations start here.
pub fn split_inputs(
    graph: &FactorGraph,
    schedule: &Schedule,
) -> (Vec<(MsgId, EdgeId)>, Vec<(MsgId, EdgeId)>) {
    let mut preloaded = Vec::new();
    let mut streamed = Vec::new();
    for (mid, eid) in &schedule.inputs {
        if graph.edges[eid.0].stream_group.is_some() {
            streamed.push((*mid, *eid));
        } else {
            preloaded.push((*mid, *eid));
        }
    }
    streamed.sort_by_key(|(mid, _)| mid.0);
    (preloaded, streamed)
}

/// Label of an edge (input-binding helper for `match`-by-label apps).
pub fn edge_label<'g>(graph: &'g FactorGraph, eid: EdgeId) -> &'g str {
    &graph.edges[eid.0].label
}

/// Bind `values` to the streamed inputs of a schedule in section order,
/// erroring on a count mismatch.
pub fn bind_streamed(
    graph: &FactorGraph,
    schedule: &Schedule,
    values: &[GaussMessage],
    map: &mut HashMap<MsgId, GaussMessage>,
) -> Result<()> {
    let (_, streamed) = split_inputs(graph, schedule);
    if streamed.len() != values.len() {
        bail!(
            "workload supplies {} streamed messages but the graph has {} streamed input edges",
            values.len(),
            streamed.len()
        );
    }
    for ((mid, _), v) in streamed.iter().zip(values) {
        map.insert(*mid, v.clone());
    }
    Ok(())
}

/// Find the single preloaded input edge with the given label.
pub fn preload_id(
    graph: &FactorGraph,
    schedule: &Schedule,
    label: &str,
) -> Result<MsgId> {
    schedule
        .inputs
        .iter()
        .find(|(_, eid)| graph.edges[eid.0].label == label)
        .map(|(mid, _)| *mid)
        .with_context(|| format!("graph has no input edge labelled '{label}'"))
}
