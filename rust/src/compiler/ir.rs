//! Compiler IR: the datapath ops of §II over *virtual* message ids.
//!
//! One IR op corresponds to one FGP instruction; the only difference from
//! [`crate::isa::Instr`] is that operands name virtual [`MsgId`]s (one per
//! distinct message, Fig. 7 left) instead of physical memory slots. The
//! allocator rewrites ids to slots; codegen then maps 1:1 onto `Instr`.

use crate::gmp::graph::StateId;
use crate::gmp::MsgId;

/// A virtual operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VOperand {
    /// A message (virtual id).
    Msg(MsgId),
    /// A state matrix.
    State(StateId),
    /// The systolic array's accumulator planes (chained intermediate).
    Acc,
}

/// Lowered op (1:1 with datapath instructions plus `smm`).
#[derive(Clone, Debug, PartialEq)]
pub enum LowOp {
    /// Matrix-multiply-accumulate into the array's StateReg planes.
    Mma { a: VOperand, a_herm: bool, b: VOperand, b_herm: bool, neg: bool, vec: bool },
    /// Multiply + per-element add of `c` (the `G = V_Y + A t1` form).
    Mms { a: VOperand, a_herm: bool, b: VOperand, b_herm: bool, c: MsgId, neg: bool, vec: bool },
    /// Faddeev elimination step producing the Schur complement.
    Fad { g: VOperand, b: VOperand, b_herm: bool, c: VOperand, d: MsgId },
    /// Commit the array's StateReg planes to message slot `dst`.
    Smm { dst: MsgId },
}

impl LowOp {
    /// Message ids this op reads.
    pub fn msg_reads(&self) -> Vec<MsgId> {
        let mut out = Vec::new();
        let push = |out: &mut Vec<MsgId>, v: &VOperand| {
            if let VOperand::Msg(m) = v {
                out.push(*m);
            }
        };
        match self {
            LowOp::Mma { a, b, .. } => {
                push(&mut out, a);
                push(&mut out, b);
            }
            LowOp::Mms { a, b, c, .. } => {
                push(&mut out, a);
                push(&mut out, b);
                out.push(*c);
            }
            LowOp::Fad { g, b, c, d, .. } => {
                push(&mut out, g);
                push(&mut out, b);
                push(&mut out, c);
                out.push(*d);
            }
            LowOp::Smm { .. } => {}
        }
        out
    }

    /// Message id this op writes (only `smm` commits to memory).
    pub fn msg_write(&self) -> Option<MsgId> {
        match self {
            LowOp::Smm { dst } => Some(*dst),
            _ => None,
        }
    }

    /// True for ops that occupy the datapath (everything but `smm`).
    pub fn is_datapath(&self) -> bool {
        !matches!(self, LowOp::Smm { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_and_writes() {
        let op = LowOp::Mms {
            a: VOperand::State(StateId(0)),
            a_herm: false,
            b: VOperand::Msg(MsgId(3)),
            b_herm: false,
            c: MsgId(5),
            neg: true,
            vec: false,
        };
        assert_eq!(op.msg_reads(), vec![MsgId(3), MsgId(5)]);
        assert_eq!(op.msg_write(), None);
        let smm = LowOp::Smm { dst: MsgId(7) };
        assert_eq!(smm.msg_write(), Some(MsgId(7)));
        assert!(smm.msg_reads().is_empty());
        assert!(!smm.is_datapath());
        assert!(op.is_datapath());
    }
}
