//! Message-memory allocation: liveness + score-based remapping (Fig. 7).
//!
//! Fig. 7 left is the *unoptimized* mapping: every message keeps its own
//! identifier, so memory grows with the schedule. Fig. 7 right is the
//! paper's optimization: "Sequentially, for each output message, the set
//! of identifiers assigned to messages that are no longer needed is
//! considered. A score is computed for each identifier in the set and the
//! output message will be remapped to the identifier having the highest
//! score."
//!
//! The score policy is configurable; the default (most-recently-freed)
//! reuses the hottest slot, which both minimizes the slot count and makes
//! sectioned schedules *periodic* — the property loop compression needs.
//!
//! Streamed inputs (observations) are handled before scoring: every
//! message in a stream group shares one slot which the host refills via
//! the Data-in port between sections.

use crate::gmp::graph::StateId;
use crate::gmp::{MsgId, Schedule};

use super::ir::LowOp;
use super::CompileError;

/// How to score free identifiers when remapping an output (paper §IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScorePolicy {
    /// Highest score to the identifier freed most recently (LIFO reuse).
    #[default]
    MostRecentlyFreed,
    /// Highest score to the lowest-numbered identifier.
    LowestIndex,
    /// Highest score to the identifier freed least recently (FIFO reuse).
    LeastRecentlyFreed,
}

/// Allocation options.
#[derive(Clone, Copy, Debug)]
pub struct AllocOptions {
    /// Apply the Fig. 7 optimization (false = identity mapping).
    pub optimize: bool,
    /// Scoring policy for the slot-reuse heuristic.
    pub policy: ScorePolicy,
    /// Message-memory capacity in slots.
    pub capacity: usize,
}

impl Default for AllocOptions {
    fn default() -> Self {
        AllocOptions { optimize: true, policy: ScorePolicy::default(), capacity: 48 }
    }
}

/// The physical memory contract between host and FGP.
#[derive(Clone, Debug)]
pub struct MemoryMap {
    /// Virtual message id -> physical slot (None if never materialized).
    pub msg_to_slot: Vec<Option<u8>>,
    /// Number of distinct physical slots used.
    pub num_slots: usize,
    /// Messages the host preloads: (virtual id, slot).
    pub preloads: Vec<(MsgId, u8)>,
    /// Stream groups: (group, slot, ordered message ids fed per section).
    pub streams: Vec<(u32, u8, Vec<MsgId>)>,
    /// Messages the host reads back: (virtual id, slot).
    pub outputs: Vec<(MsgId, u8)>,
    /// Virtual state id -> physical state-memory slot.
    pub state_to_slot: Vec<u8>,
    /// Number of distinct state-memory slots used.
    pub num_state_slots: usize,
    /// Resident states the host preloads once: (virtual state id, slot).
    pub state_preloads: Vec<(StateId, u8)>,
    /// State stream groups: (group, slot, ordered state ids fed per section).
    pub state_streams: Vec<(u32, u8, Vec<StateId>)>,
}

impl MemoryMap {
    /// Physical slot assigned to a virtual message, if resident.
    pub fn slot_of(&self, m: MsgId) -> Option<u8> {
        self.msg_to_slot.get(m.0).copied().flatten()
    }

    /// Physical state-memory slot of a state matrix.
    pub fn state_slot_of(&self, s: StateId) -> u8 {
        self.state_to_slot[s.0]
    }
}

/// Map virtual state ids onto physical state-memory slots: resident
/// states get their own slot, streamed states share one slot per group.
///
/// `stream_groups[i]` is the group of virtual state `i`; entries past the
/// end (the compiler's identity matrix) are treated as resident.
pub fn allocate_states(
    num_states: usize,
    stream_groups: &[Option<u32>],
    capacity: usize,
) -> Result<(Vec<u8>, usize, Vec<(StateId, u8)>, Vec<(u32, u8, Vec<StateId>)>), CompileError> {
    let mut state_to_slot = vec![0u8; num_states];
    let mut next = 0usize;
    let mut preloads = Vec::new();
    let mut streams: Vec<(u32, u8, Vec<StateId>)> = Vec::new();
    for i in 0..num_states {
        let group = stream_groups.get(i).copied().flatten();
        match group {
            Some(g) => match streams.iter_mut().find(|(sg, _, _)| *sg == g) {
                Some((_, slot, members)) => {
                    state_to_slot[i] = *slot;
                    members.push(StateId(i));
                }
                None => {
                    let slot = next as u8;
                    next += 1;
                    state_to_slot[i] = slot;
                    streams.push((g, slot, vec![StateId(i)]));
                }
            },
            None => {
                let slot = next as u8;
                next += 1;
                state_to_slot[i] = slot;
                preloads.push((StateId(i), slot));
            }
        }
    }
    if next > capacity {
        return Err(CompileError::OutOfStateMemory { needed: next, available: capacity });
    }
    Ok((state_to_slot, next, preloads, streams))
}

/// Assign physical slots to every virtual message id.
pub fn allocate(
    schedule: &Schedule,
    ops: &[LowOp],
    opts: &AllocOptions,
) -> Result<MemoryMap, CompileError> {
    let n = schedule.num_msgs;
    let mut msg_to_slot: Vec<Option<u8>> = vec![None; n];
    let mut next_slot: usize = 0;
    let mut alloc_new = |msg_to_slot: &mut Vec<Option<u8>>, m: MsgId| -> usize {
        let s = next_slot;
        msg_to_slot[m.0] = Some(s as u8);
        next_slot += 1;
        s
    };

    // --- streamed inputs: one shared slot per group, in schedule order
    let mut streams: Vec<(u32, u8, Vec<MsgId>)> = Vec::new();
    for (mid, group) in &schedule.streams {
        match streams.iter_mut().find(|(g, _, _)| g == group) {
            Some((_, slot, members)) => {
                msg_to_slot[mid.0] = Some(*slot);
                members.push(*mid);
            }
            None => {
                let s = alloc_new(&mut msg_to_slot, *mid) as u8;
                streams.push((*group, s, vec![*mid]));
            }
        }
    }

    // --- preloaded inputs (non-streamed)
    let mut preloads = Vec::new();
    for (mid, _) in &schedule.inputs {
        if schedule.is_streamed(*mid) {
            continue;
        }
        let s = alloc_new(&mut msg_to_slot, *mid) as u8;
        preloads.push((*mid, s));
    }

    // --- last use of each message over the op stream
    let mut last_use: Vec<isize> = vec![-1; n];
    for (i, op) in ops.iter().enumerate() {
        for r in op.msg_reads() {
            last_use[r.0] = i as isize;
        }
    }
    for (mid, _) in &schedule.outputs {
        last_use[mid.0] = isize::MAX; // program outputs never die
    }

    if !opts.optimize {
        // Fig. 7 left: every produced message gets its own identifier.
        for op in ops {
            if let Some(dst) = op.msg_write() {
                if msg_to_slot[dst.0].is_none() {
                    alloc_new(&mut msg_to_slot, dst);
                }
            }
        }
    } else {
        // Fig. 7 right: score-based remapping onto dead identifiers.
        // free pool entries: (slot, freed_at_op)
        let mut free: Vec<(u8, usize)> = Vec::new();
        // slots owned by live messages: (slot, owner)
        let mut live: Vec<(u8, MsgId)> = Vec::new();
        for (mid, s) in &preloads {
            live.push((*s, *mid));
        }
        // stream slots are permanently reserved (refilled every section)
        for (i, op) in ops.iter().enumerate() {
            if let Some(dst) = op.msg_write() {
                if msg_to_slot[dst.0].is_none() {
                    let slot = if let Some(best) = pick_free(&mut free, opts.policy) {
                        best
                    } else {
                        alloc_new(&mut msg_to_slot, dst) as u8
                    };
                    msg_to_slot[dst.0] = Some(slot);
                    live.push((slot, dst));
                }
            }
            // retire messages whose last use was this op
            let mut j = 0;
            while j < live.len() {
                let (slot, owner) = live[j];
                if last_use[owner.0] <= i as isize {
                    free.push((slot, i));
                    live.swap_remove(j);
                } else {
                    j += 1;
                }
            }
        }
    }

    if next_slot > opts.capacity {
        return Err(CompileError::OutOfMemory { needed: next_slot, available: opts.capacity });
    }

    let outputs = schedule
        .outputs
        .iter()
        .filter_map(|(mid, _)| msg_to_slot[mid.0].map(|s| (*mid, s)))
        .collect();

    Ok(MemoryMap {
        msg_to_slot,
        num_slots: next_slot,
        preloads,
        streams,
        outputs,
        state_to_slot: Vec::new(),
        num_state_slots: 0,
        state_preloads: Vec::new(),
        state_streams: Vec::new(),
    })
}

/// Pick (and remove) the highest-scoring free identifier, if any.
fn pick_free(free: &mut Vec<(u8, usize)>, policy: ScorePolicy) -> Option<u8> {
    if free.is_empty() {
        return None;
    }
    let idx = match policy {
        ScorePolicy::MostRecentlyFreed => {
            // score = freed_at (ties: higher slot)
            (0..free.len()).max_by_key(|&i| (free[i].1, free[i].0)).unwrap()
        }
        ScorePolicy::LowestIndex => {
            (0..free.len()).min_by_key(|&i| free[i].0).unwrap()
        }
        ScorePolicy::LeastRecentlyFreed => {
            (0..free.len()).min_by_key(|&i| (free[i].1, free[i].0)).unwrap()
        }
    };
    Some(free.swap_remove(idx).0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lower::lower;
    use crate::gmp::matrix::CMatrix;
    use crate::gmp::{FactorGraph, Schedule};
    use crate::testutil::Rng;

    fn rls(sections: usize) -> (FactorGraph, Schedule) {
        let mut rng = Rng::new(1);
        let mut g = FactorGraph::new();
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
        g.rls_chain(4, &a_list);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    #[test]
    fn unoptimized_grows_with_sections() {
        for sections in [2usize, 4, 8] {
            let (g, s) = rls(sections);
            let lowered = lower(&g, &s).unwrap();
            let map = allocate(
                &s,
                &lowered.ops,
                &AllocOptions { optimize: false, ..Default::default() },
            )
            .unwrap();
            // prior + stream slot + one per section output
            assert_eq!(map.num_slots, 2 + sections, "sections={sections}");
        }
    }

    #[test]
    fn optimized_is_constant_in_sections() {
        for sections in [2usize, 4, 16] {
            let (g, s) = rls(sections);
            let lowered = lower(&g, &s).unwrap();
            let map = allocate(&s, &lowered.ops, &AllocOptions::default()).unwrap();
            // stream slot + state slot (prior reused in place)
            assert_eq!(map.num_slots, 2, "sections={sections}");
        }
    }

    #[test]
    fn optimized_reuses_state_slot_in_place() {
        let (g, s) = rls(3);
        let lowered = lower(&g, &s).unwrap();
        let map = allocate(&s, &lowered.ops, &AllocOptions::default()).unwrap();
        // prior and all chained outputs share one slot
        let prior_slot = map.preloads[0].1;
        for step in &s.steps {
            assert_eq!(map.slot_of(step.out), Some(prior_slot));
        }
    }

    #[test]
    fn stream_group_shares_one_slot() {
        let (g, s) = rls(5);
        let lowered = lower(&g, &s).unwrap();
        let map = allocate(&s, &lowered.ops, &AllocOptions::default()).unwrap();
        assert_eq!(map.streams.len(), 1);
        let (_, slot, members) = &map.streams[0];
        assert_eq!(members.len(), 5);
        for m in members {
            assert_eq!(map.slot_of(*m), Some(*slot));
        }
    }

    #[test]
    fn capacity_exceeded_errors() {
        let (g, s) = rls(8);
        let lowered = lower(&g, &s).unwrap();
        let err = allocate(
            &s,
            &lowered.ops,
            &AllocOptions { optimize: false, capacity: 4, ..Default::default() },
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::OutOfMemory { .. }));
    }

    #[test]
    fn no_two_live_messages_share_a_slot() {
        // Safety invariant of the allocator, checked densely.
        let (g, s) = rls(6);
        let lowered = lower(&g, &s).unwrap();
        let map = allocate(&s, &lowered.ops, &AllocOptions::default()).unwrap();
        // recompute liveness and walk ops checking overlap
        let mut last_use = vec![-1isize; s.num_msgs];
        for (i, op) in lowered.ops.iter().enumerate() {
            for r in op.msg_reads() {
                last_use[r.0] = i as isize;
            }
        }
        for (mid, _) in &s.outputs {
            last_use[mid.0] = isize::MAX;
        }
        let mut def_at = vec![isize::MAX; s.num_msgs];
        for (mid, _) in &s.inputs {
            def_at[mid.0] = -1;
        }
        for (i, op) in lowered.ops.iter().enumerate() {
            if let Some(d) = op.msg_write() {
                def_at[d.0] = i as isize;
            }
        }
        for a in 0..s.num_msgs {
            for b in (a + 1)..s.num_msgs {
                let (sa, sb) = (map.slot_of(MsgId(a)), map.slot_of(MsgId(b)));
                if sa.is_none() || sa != sb {
                    continue;
                }
                // same slot: live ranges must not overlap, unless both are
                // in the same stream group (sequential by construction)
                let same_stream = s.is_streamed(MsgId(a)) && s.is_streamed(MsgId(b));
                if same_stream {
                    continue;
                }
                let overlap = def_at[a] < last_use[b] && def_at[b] < last_use[a];
                assert!(!overlap, "messages {a} and {b} overlap in slot {sa:?}");
            }
        }
    }

    #[test]
    fn policies_all_produce_valid_small_maps() {
        let (g, s) = rls(4);
        let lowered = lower(&g, &s).unwrap();
        for policy in [
            ScorePolicy::MostRecentlyFreed,
            ScorePolicy::LowestIndex,
            ScorePolicy::LeastRecentlyFreed,
        ] {
            let map = allocate(
                &s,
                &lowered.ops,
                &AllocOptions { policy, ..Default::default() },
            )
            .unwrap();
            assert!(map.num_slots <= 3, "{policy:?} used {}", map.num_slots);
        }
    }
}
