//! Loop compression (paper §IV: "This program is compressed using the
//! loop instruction").
//!
//! After allocation, the per-section instruction sequences of a
//! repetitive factor graph are bit-identical; this pass finds the best
//! repeated contiguous pattern and replaces repeats 2..k with a single
//! `loop k p` instruction (k total passes over the previous p
//! instructions — the first pass remains inline, exactly the FSM
//! semantics of [`crate::isa::Program::unrolled`]).

use crate::isa::Instr;

/// Result of compression.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// The (possibly loop-compressed) instruction stream.
    pub instrs: Vec<Instr>,
    /// (start, period, passes) of the loop found, if any.
    pub looped: Option<(usize, usize, usize)>,
}

/// Maximum loop body length encodable in the ISA.
const MAX_BODY: usize = u8::MAX as usize;
/// Maximum total passes encodable in the ISA.
const MAX_COUNT: usize = u16::MAX as usize;

/// Find the single best loop (max instruction savings) and rewrite.
///
/// Savings for a pattern of period `p` repeated `k` times = `(k-1)*p - 1`
/// (the removed copies minus the inserted `loop`). Programs with no
/// repeats are returned unchanged.
pub fn compress(instrs: &[Instr]) -> Compressed {
    let n = instrs.len();
    let mut best: Option<(usize, usize, usize, isize)> = None; // start, p, k, savings

    for p in 1..=n / 2 {
        if p > MAX_BODY {
            break;
        }
        let mut start = 0;
        while start + 2 * p <= n {
            // count consecutive repeats of instrs[start..start+p]
            let mut k = 1;
            while start + (k + 1) * p <= n
                && instrs[start + k * p..start + (k + 1) * p] == instrs[start..start + p]
                && k + 1 <= MAX_COUNT
            {
                k += 1;
            }
            if k >= 2 {
                let savings = ((k - 1) * p) as isize - 1;
                if best.map_or(true, |(_, _, _, s)| savings > s) {
                    best = Some((start, p, k, savings));
                }
                start += k * p; // skip past this run
            } else {
                start += 1;
            }
        }
    }

    match best {
        Some((start, p, k, savings)) if savings > 0 => {
            let mut out = Vec::with_capacity(n - savings as usize);
            out.extend_from_slice(&instrs[..start + p]);
            out.push(Instr::Loop { count: k as u16, body: p as u8 });
            out.extend_from_slice(&instrs[start + k * p..]);
            Compressed { instrs: out, looped: Some((start, p, k)) }
        }
        _ => Compressed { instrs: instrs.to_vec(), looped: None },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OperandSrc;

    fn mma(slot: u8) -> Instr {
        Instr::Mma {
            a: OperandSrc::Msg(slot),
            a_herm: false,
            b: OperandSrc::State(0),
            b_herm: true,
            neg: false,
            vec: false,
        }
    }

    fn smm(dst: u8) -> Instr {
        Instr::Smm { dst }
    }

    #[test]
    fn compresses_repeated_sections() {
        // 4 identical sections of 3 instrs
        let section = vec![mma(1), smm(2), smm(3)];
        let mut instrs = Vec::new();
        for _ in 0..4 {
            instrs.extend(section.clone());
        }
        let c = compress(&instrs);
        assert_eq!(c.looped, Some((0, 3, 4)));
        assert_eq!(c.instrs.len(), 4); // 3 body + 1 loop
        assert_eq!(c.instrs[3], Instr::Loop { count: 4, body: 3 });
    }

    #[test]
    fn unrolls_back_to_original() {
        let section = vec![mma(1), smm(2)];
        let mut instrs = Vec::new();
        for _ in 0..5 {
            instrs.extend(section.clone());
        }
        let c = compress(&instrs);
        let p = crate::isa::Program::new(c.instrs);
        assert_eq!(p.unrolled(), instrs);
    }

    #[test]
    fn no_repeats_unchanged() {
        let instrs = vec![mma(1), smm(2), mma(3), smm(4)];
        let c = compress(&instrs);
        assert!(c.looped.is_none());
        assert_eq!(c.instrs, instrs);
    }

    #[test]
    fn prefix_preserved() {
        // prologue then repeats
        let mut instrs = vec![smm(9)];
        for _ in 0..3 {
            instrs.extend([mma(1), smm(2)]);
        }
        let c = compress(&instrs);
        assert_eq!(c.looped, Some((1, 2, 3)));
        assert_eq!(c.instrs[0], smm(9));
        let p = crate::isa::Program::new(c.instrs);
        assert_eq!(p.unrolled(), instrs);
    }

    #[test]
    fn single_instruction_period() {
        let instrs = vec![smm(1); 10];
        let c = compress(&instrs);
        assert_eq!(c.looped, Some((0, 1, 10)));
        assert_eq!(c.instrs.len(), 2);
        let p = crate::isa::Program::new(c.instrs);
        assert_eq!(p.unrolled(), instrs);
    }

    #[test]
    fn two_instr_repeat_saves_nothing_when_short() {
        // k=2, p=1 -> savings 0: must NOT compress (loop costs one instr)
        let instrs = vec![smm(1), smm(1)];
        let c = compress(&instrs);
        assert!(c.looped.is_none());
    }

    #[test]
    fn picks_larger_savings() {
        // small repeat early, big repeat later: must pick the big one
        let mut instrs = vec![smm(1), smm(1), smm(1)];
        for _ in 0..8 {
            instrs.extend([mma(2), smm(3), mma(4), smm(5)]);
        }
        let c = compress(&instrs);
        let (start, p, k) = c.looped.unwrap();
        assert_eq!((start, p, k), (3, 4, 8));
        let prog = crate::isa::Program::new(c.instrs);
        assert_eq!(prog.unrolled(), instrs);
    }
}
