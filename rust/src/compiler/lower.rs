//! Lowering: node updates → datapath ops (the Fig. 2 decomposition).
//!
//! Each GMP node type expands into a short `mma`/`mms`/`fad`/`smm`
//! sequence using the accumulator chaining of §II ("the result of the
//! matrix multiplication in accum mode ... is used as input to the matrix
//! multiplication in shift mode and as input to the Faddeev algorithm").
//!
//! The compound-observation node — the paper's benchmark op — lowers to
//! 4 datapath instructions + 1 store:
//!
//! ```text
//! mma  x  sAh      ; accum  = V_X A^H               (T1)
//! mms  sA acc y    ; shift  = V_Y + A*T1            (G)
//! mms  sA x  y v ~ ; vshift = A m_X - m_Y           (negated innovation)
//! fad  acc acch acc x ; Faddeev over [[G, T1^H | -r],[T1, V_X | m_X]]
//! smm  z           ; store (V_Z, m_Z)
//! ```
//!
//! The innovation is streamed *negated* (`mms` negates its addend) so the
//! Faddeev elimination `x - C G^{-1} y` lands on
//! `m_X + T1 G^{-1} (m_Y - A m_X)` with the correct sign — the same trick
//! the Pallas kernel uses (python/compile/kernels/compound.py).
//!
//! (The paper's Listing 2 shows two `mma`+`mms` pairs per section; our
//! mean pipeline folds its `mma` into the `mms` via the Select unit, so
//! we emit one pair plus the vector `mms` — same op count ±1, same
//! dataflow. Documented in DESIGN.md §ISA.)
//!
//! Additive/equality nodes multiply by a compiler-provided **identity
//! state matrix** so the sum rides the `mms` adder, exactly how a
//! multiply-free op uses a MAC array.

use crate::gmp::graph::StateId;
use crate::gmp::schedule::{Schedule, StepOp};
use crate::gmp::{FactorGraph, MsgId};

use super::ir::{LowOp, VOperand};
use super::CompileError;

/// Output of lowering: the op stream plus the (possibly extended) state
/// table — lowering may append an identity matrix for add/equality nodes.
#[derive(Clone, Debug)]
pub struct Lowered {
    /// Lowered ops in schedule order.
    pub ops: Vec<LowOp>,
    /// Index of the identity state matrix, if any node needed one.
    pub identity_state: Option<StateId>,
    /// Number of state matrices after lowering (graph states + identity).
    pub num_states: usize,
    /// Section boundaries: op index where each schedule step's ops begin
    /// (used by loop compression and cycle accounting).
    pub step_starts: Vec<usize>,
}

/// Expand every schedule step into datapath ops.
pub fn lower(graph: &FactorGraph, schedule: &Schedule) -> Result<Lowered, CompileError> {
    let mut ops = Vec::new();
    let mut step_starts = Vec::with_capacity(schedule.steps.len());
    let mut identity_state = None;
    let mut num_states = graph.states.len();
    let mut defined: Vec<bool> = vec![false; schedule.num_msgs];
    for (mid, _) in &schedule.inputs {
        defined[mid.0] = true;
    }

    let need_identity = |identity_state: &mut Option<StateId>, num_states: &mut usize| {
        *identity_state.get_or_insert_with(|| {
            let id = StateId(*num_states);
            *num_states += 1;
            id
        })
    };

    for (i, step) in schedule.steps.iter().enumerate() {
        step_starts.push(ops.len());
        // use-before-def check (compiler invariant)
        for input in step.op.inputs() {
            if !defined[input.0] {
                return Err(CompileError::UseBeforeDef { step: i, msg: input.0 });
            }
        }
        match &step.op {
            StepOp::CompoundObservation { x, y, a } => {
                lower_compound_observation(&mut ops, *x, *y, *a, step.out);
            }
            StepOp::CompoundEquality { x, y, a } => {
                lower_compound_equality(&mut ops, *x, *y, *a, step.out);
            }
            StepOp::Multiply { x, a } => {
                lower_multiply(&mut ops, *x, *a, step.out);
            }
            StepOp::Add { x, y } | StepOp::Equality { x, y } => {
                // Equality is the same additive rule in weight form; the
                // front-end is responsible for storing those messages in
                // weight form (see gmp::nodes docs).
                let id = need_identity(&mut identity_state, &mut num_states);
                lower_add(&mut ops, *x, *y, id, step.out);
            }
        }
        defined[step.out.0] = true;
    }

    Ok(Lowered { ops, identity_state, num_states, step_starts })
}

/// Compound observation node (Kalman measurement update) — see module doc.
fn lower_compound_observation(ops: &mut Vec<LowOp>, x: MsgId, y: MsgId, a: StateId, out: MsgId) {
    // accum = V_X * A^H  (T1)
    ops.push(LowOp::Mma {
        a: VOperand::Msg(x),
        a_herm: false,
        b: VOperand::State(a),
        b_herm: true,
        neg: false,
        vec: false,
    });
    // shift = V_Y + A * accum  (G) — rides the free adder slots (§II)
    ops.push(LowOp::Mms {
        a: VOperand::State(a),
        a_herm: false,
        b: VOperand::Acc,
        b_herm: false,
        c: y,
        neg: false,
        vec: false,
    });
    // vshift = A m_X - m_Y  (negated innovation), mean pipeline
    ops.push(LowOp::Mms {
        a: VOperand::State(a),
        a_herm: false,
        b: VOperand::Msg(x),
        b_herm: false,
        c: y,
        neg: true,
        vec: true,
    });
    // Faddeev over [[G, T1^H | -r], [T1, V_X | m_X]] -> (V_Z, m_Z):
    //   V_Z = V_X - T1 G^{-1} T1^H,  m_Z = m_X + T1 G^{-1} r
    // G comes from the shift plane (acc), T1 from the accum plane (acc),
    // B = T1^H via the Transpose unit.
    ops.push(LowOp::Fad {
        g: VOperand::Acc,
        b: VOperand::Acc,
        b_herm: true,
        c: VOperand::Acc,
        d: x,
    });
    ops.push(LowOp::Smm { dst: out });
}

/// Compound equality-multiplier node in weight form:
/// `W_Z = W_X + A^H W_Y A`, `(Wm)_Z = (Wm)_X + A^H (Wm)_Y`.
fn lower_compound_equality(ops: &mut Vec<LowOp>, x: MsgId, y: MsgId, a: StateId, out: MsgId) {
    // accum = W_Y * A
    ops.push(LowOp::Mma {
        a: VOperand::Msg(y),
        a_herm: false,
        b: VOperand::State(a),
        b_herm: false,
        neg: false,
        vec: false,
    });
    // shift = W_X + A^H * accum
    ops.push(LowOp::Mms {
        a: VOperand::State(a),
        a_herm: true,
        b: VOperand::Acc,
        b_herm: false,
        c: x,
        neg: false,
        vec: false,
    });
    // vshift = (Wm)_X + A^H * (Wm)_Y
    ops.push(LowOp::Mms {
        a: VOperand::State(a),
        a_herm: true,
        b: VOperand::Msg(y),
        b_herm: false,
        c: x,
        neg: false,
        vec: true,
    });
    ops.push(LowOp::Smm { dst: out });
}

/// Multiplier node: V_Y = A V_X A^H, m_Y = A m_X.
fn lower_multiply(ops: &mut Vec<LowOp>, x: MsgId, a: StateId, out: MsgId) {
    // accum = V_X * A^H
    ops.push(LowOp::Mma {
        a: VOperand::Msg(x),
        a_herm: false,
        b: VOperand::State(a),
        b_herm: true,
        neg: false,
        vec: false,
    });
    // accum = A * accum  (chained second multiply)
    ops.push(LowOp::Mma {
        a: VOperand::State(a),
        a_herm: false,
        b: VOperand::Acc,
        b_herm: false,
        neg: false,
        vec: false,
    });
    // vaccum = A * m_X
    ops.push(LowOp::Mma {
        a: VOperand::State(a),
        a_herm: false,
        b: VOperand::Msg(x),
        b_herm: false,
        neg: false,
        vec: true,
    });
    ops.push(LowOp::Smm { dst: out });
}

/// Additive node via the identity state matrix: Z = X + Y in both planes.
fn lower_add(ops: &mut Vec<LowOp>, x: MsgId, y: MsgId, identity: StateId, out: MsgId) {
    ops.push(LowOp::Mms {
        a: VOperand::State(identity),
        a_herm: false,
        b: VOperand::Msg(x),
        b_herm: false,
        c: y,
        neg: false,
        vec: false,
    });
    ops.push(LowOp::Mms {
        a: VOperand::State(identity),
        a_herm: false,
        b: VOperand::Msg(x),
        b_herm: false,
        c: y,
        neg: false,
        vec: true,
    });
    ops.push(LowOp::Smm { dst: out });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::CMatrix;
    use crate::gmp::Schedule;
    use crate::testutil::Rng;

    fn rls_graph(sections: usize) -> (FactorGraph, Schedule) {
        let mut rng = Rng::new(1);
        let mut g = FactorGraph::new();
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
        g.rls_chain(4, &a_list);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    #[test]
    fn compound_lowers_to_five_ops() {
        let (g, s) = rls_graph(1);
        let lowered = lower(&g, &s).unwrap();
        assert_eq!(lowered.ops.len(), 5);
        assert!(matches!(lowered.ops[0], LowOp::Mma { .. }));
        assert!(matches!(lowered.ops[3], LowOp::Fad { .. }));
        assert!(matches!(lowered.ops[4], LowOp::Smm { .. }));
        assert!(lowered.identity_state.is_none());
    }

    #[test]
    fn sections_produce_identical_shapes() {
        let (g, s) = rls_graph(3);
        let lowered = lower(&g, &s).unwrap();
        assert_eq!(lowered.ops.len(), 15);
        assert_eq!(lowered.step_starts, vec![0, 5, 10]);
    }

    #[test]
    fn add_node_allocates_identity_once() {
        let mut g = FactorGraph::new();
        let x = g.add_input_edge(4, "x");
        let y = g.add_input_edge(4, "y");
        let z = g.add_edge(4, "z");
        let w = g.add_input_edge(4, "w");
        let z2 = g.add_edge(4, "z2");
        g.add_node(crate::gmp::NodeKind::Add, vec![x, y], z, "add1");
        g.add_node(crate::gmp::NodeKind::Add, vec![z, w], z2, "add2");
        g.mark_output(z2);
        let s = Schedule::forward_sweep(&g);
        let lowered = lower(&g, &s).unwrap();
        assert_eq!(lowered.identity_state, Some(StateId(0)));
        assert_eq!(lowered.num_states, 1); // shared between the two adds
    }

    #[test]
    fn use_before_def_is_rejected() {
        use crate::gmp::schedule::{ScheduleStep, StepOp};
        let g = FactorGraph::new();
        let bogus = Schedule {
            steps: vec![ScheduleStep {
                node: crate::gmp::NodeId(0),
                op: StepOp::Add { x: MsgId(0), y: MsgId(1) },
                out: MsgId(2),
            }],
            inputs: vec![],
            outputs: vec![],
            streams: vec![],
            num_msgs: 3,
        };
        assert_eq!(
            lower(&g, &bogus).unwrap_err(),
            CompileError::UseBeforeDef { step: 0, msg: 0 }
        );
    }
}
