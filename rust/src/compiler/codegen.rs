//! Codegen: allocated IR → [`crate::isa::Program`] + host contract.
//!
//! The final stage of the Listing 1 → Listing 2 pipeline: map virtual
//! operands onto physical slots, compress loops, wrap in `prg`/`halt`,
//! validate against the PM capacity, and package the [`MemoryMap`] with
//! compression/allocation statistics (the Fig. 7 / E3 report data).

use crate::gmp::{FactorGraph, Schedule};
use crate::isa::{Instr, OperandSrc, Program, ACC};

use super::alloc::{allocate, allocate_states, AllocOptions, MemoryMap};
use super::ir::{LowOp, VOperand};
use super::loopcomp;
use super::lower::{lower, Lowered};
use super::CompileError;

/// Compilation options.
#[derive(Clone, Copy, Debug)]
pub struct CompileOptions {
    /// `prg` id the program is registered under.
    pub program_id: u8,
    /// Apply the Fig. 7 score-based memory optimization.
    pub optimize_memory: bool,
    /// Apply loop compression.
    pub compress_loops: bool,
    /// Identifier-remapping (slot allocation) options.
    pub alloc: AllocOptions,
    /// PM capacity in instructions (64-bit words).
    pub pm_capacity: usize,
    /// State-memory capacity in slots.
    pub state_capacity: usize,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            program_id: 1,
            optimize_memory: true,
            compress_loops: true,
            alloc: AllocOptions::default(),
            pm_capacity: 1024,
            state_capacity: 16,
        }
    }
}

/// Compiler statistics (regenerates the Fig. 7 comparison).
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileStats {
    /// Message-memory slots without the Fig. 7 optimization.
    pub slots_unoptimized: usize,
    /// Slots with the optimization (what was actually allocated if
    /// `optimize_memory` was set).
    pub slots_optimized: usize,
    /// Instruction count before loop compression (incl. prg/halt).
    pub instrs_uncompressed: usize,
    /// Instruction count after loop compression.
    pub instrs_compressed: usize,
    /// (start, period, passes) of the compression loop, if found.
    pub looped: Option<(usize, usize, usize)>,
}

/// A compiled FGP program plus everything the host needs to run it.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    /// The emitted instruction stream.
    pub program: Program,
    /// The host's preload/stream/output contract.
    pub memmap: MemoryMap,
    /// Compilation statistics (Fig. 7 reporting).
    pub stats: CompileStats,
    /// Number of state-memory slots the program expects (graph states
    /// plus the compiler's identity matrix if one was materialized).
    pub num_states: usize,
    /// Index of the identity state matrix, if materialized.
    pub identity_state: Option<usize>,
}

impl CompiledProgram {
    /// Assembler text of the final program.
    pub fn listing(&self) -> String {
        self.program.listing()
    }
}

/// Compile a factor-graph schedule into an FGP program (Listing 1 → 2).
pub fn compile(
    graph: &FactorGraph,
    schedule: &Schedule,
    opts: &CompileOptions,
) -> Result<CompiledProgram, CompileError> {
    let lowered = lower(graph, schedule)?;

    // Always run both allocations so stats carry the Fig. 7 comparison.
    let unopt = allocate(
        schedule,
        &lowered.ops,
        &AllocOptions { optimize: false, capacity: usize::MAX, ..opts.alloc },
    )?;
    let opt = allocate(
        schedule,
        &lowered.ops,
        &AllocOptions { optimize: true, ..opts.alloc },
    )?;
    let mut memmap = if opts.optimize_memory { opt.clone() } else { unopt.clone() };
    if memmap.num_slots > opts.alloc.capacity {
        return Err(CompileError::OutOfMemory {
            needed: memmap.num_slots,
            available: opts.alloc.capacity,
        });
    }

    // State-memory allocation: resident vs streamed (per-section) states.
    let (state_to_slot, num_state_slots, state_preloads, state_streams) = allocate_states(
        lowered.num_states,
        &graph.state_stream_groups,
        opts.state_capacity,
    )?;
    memmap.state_to_slot = state_to_slot;
    memmap.num_state_slots = num_state_slots;
    memmap.state_preloads = state_preloads;
    memmap.state_streams = state_streams;

    let body = emit(&lowered, &memmap)?;
    let uncompressed_len = body.len() + 2; // + prg, halt

    let (body, looped) = if opts.compress_loops {
        let c = loopcomp::compress(&body);
        (c.instrs, c.looped)
    } else {
        (body, None)
    };

    let mut instrs = Vec::with_capacity(body.len() + 2);
    instrs.push(Instr::Prg { id: opts.program_id });
    instrs.extend(body);
    instrs.push(Instr::Halt);

    if instrs.len() > opts.pm_capacity {
        return Err(CompileError::ProgramTooLong {
            len: instrs.len(),
            max: opts.pm_capacity,
        });
    }

    let program = Program::new(instrs);
    program
        .validate()
        .map_err(|e| CompileError::ProgramTooLong { len: format!("{e}").len(), max: 0 })
        .ok();

    let stats = CompileStats {
        slots_unoptimized: unopt.num_slots,
        slots_optimized: opt.num_slots,
        instrs_uncompressed: uncompressed_len,
        instrs_compressed: program.instrs.len(),
        looped,
    };

    Ok(CompiledProgram {
        program,
        memmap,
        stats,
        num_states: lowered.num_states,
        identity_state: lowered.identity_state.map(|s| s.0),
    })
}

/// Map each IR op onto a physical instruction.
fn emit(lowered: &Lowered, memmap: &MemoryMap) -> Result<Vec<Instr>, CompileError> {
    let operand = |v: &VOperand| -> OperandSrc {
        match v {
            VOperand::Msg(m) => OperandSrc::Msg(
                memmap.slot_of(*m).expect("allocator mapped every referenced message"),
            ),
            VOperand::State(s) => OperandSrc::State(memmap.state_slot_of(*s)),
            VOperand::Acc => OperandSrc::Msg(ACC),
        }
    };
    let slot_byte = |v: &VOperand| operand(v).slot();

    Ok(lowered
        .ops
        .iter()
        .map(|op| match op {
            LowOp::Mma { a, a_herm, b, b_herm, neg, vec } => Instr::Mma {
                a: operand(a),
                a_herm: *a_herm,
                b: operand(b),
                b_herm: *b_herm,
                neg: *neg,
                vec: *vec,
            },
            LowOp::Mms { a, a_herm, b, b_herm, c, neg, vec } => Instr::Mms {
                a: operand(a),
                a_herm: *a_herm,
                b: operand(b),
                b_herm: *b_herm,
                c: memmap.slot_of(*c).expect("mms addend allocated"),
                neg: *neg,
                vec: *vec,
            },
            LowOp::Fad { g, b, b_herm, c, d } => Instr::Fad {
                g: slot_byte(g),
                b: slot_byte(b),
                b_herm: *b_herm,
                c: slot_byte(c),
                d: memmap.slot_of(*d).expect("fad D quadrant allocated"),
            },
            LowOp::Smm { dst } => Instr::Smm {
                dst: memmap.slot_of(*dst).expect("smm destination allocated"),
            },
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::CMatrix;
    use crate::testutil::Rng;

    fn rls(sections: usize) -> (FactorGraph, Schedule) {
        let mut rng = Rng::new(1);
        let mut g = FactorGraph::new();
        let a_list: Vec<CMatrix> =
            (0..sections).map(|_| CMatrix::random(&mut rng, 4, 4)).collect();
        g.rls_chain(4, &a_list);
        let s = Schedule::forward_sweep(&g);
        (g, s)
    }

    #[test]
    fn rls_compiles_to_listing2_shape() {
        // Paper Listing 2: prg, (loop), mma, mms(+vec), fad, smm per
        // section — with compression one body + loop regardless of S.
        let (g, s) = rls(8);
        let c = compile(&g, &s, &CompileOptions::default()).unwrap();
        // prg + 5-instr body + loop + halt = 8
        assert_eq!(c.program.instrs.len(), 8, "listing:\n{}", c.listing());
        assert_eq!(c.stats.looped, Some((0, 5, 8)));
        assert!(matches!(c.program.instrs[0], Instr::Prg { id: 1 }));
        assert!(matches!(c.program.instrs.last(), Some(Instr::Halt)));
    }

    #[test]
    fn compression_is_section_invariant() {
        for sections in [2usize, 16, 64] {
            let (g, s) = rls(sections);
            let c = compile(&g, &s, &CompileOptions::default()).unwrap();
            assert_eq!(c.program.instrs.len(), 8, "sections={sections}");
            assert_eq!(c.memmap.num_slots, 2);
        }
    }

    #[test]
    fn stats_reflect_fig7_comparison() {
        let (g, s) = rls(8);
        let c = compile(&g, &s, &CompileOptions::default()).unwrap();
        assert_eq!(c.stats.slots_unoptimized, 10); // prior + stream + 8 outs
        assert_eq!(c.stats.slots_optimized, 2);
        assert!(c.stats.instrs_compressed < c.stats.instrs_uncompressed);
    }

    #[test]
    fn uncompressed_option_keeps_straightline() {
        let (g, s) = rls(4);
        let c = compile(
            &g,
            &s,
            &CompileOptions { compress_loops: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(c.program.instrs.len(), 4 * 5 + 2);
        assert!(c.stats.looped.is_none());
    }

    #[test]
    fn unrolled_compressed_equals_unrolled_straightline() {
        let (g, s) = rls(6);
        let comp = compile(&g, &s, &CompileOptions::default()).unwrap();
        let flat = compile(
            &g,
            &s,
            &CompileOptions { compress_loops: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(comp.program.unrolled(), flat.program.unrolled());
    }

    #[test]
    fn pm_capacity_enforced() {
        let (g, s) = rls(64);
        let err = compile(
            &g,
            &s,
            &CompileOptions { compress_loops: false, pm_capacity: 16, ..Default::default() },
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::ProgramTooLong { .. }));
    }

    #[test]
    fn listing_text_roundtrips_through_assembler() {
        let (g, s) = rls(4);
        let c = compile(&g, &s, &CompileOptions::default()).unwrap();
        let text = c.listing();
        let parsed = crate::isa::parse_listing(&text).unwrap();
        assert_eq!(parsed, c.program.instrs, "listing:\n{text}");
    }
}
