//! S4 — The FGP compiler (paper §IV, Fig. 7, Listings 1→2).
//!
//! Pipeline, mirroring the paper:
//!
//! 1. a [`crate::gmp::Schedule`] is derived from the high-level factor
//!    graph (the "Matlab" front-end);
//! 2. [`lower`] expands each node update into the datapath ops of §II
//!    (`mma`/`mms`/`fad`/`smm`) on *virtual* message ids;
//! 3. [`alloc`] runs liveness analysis and the paper's **score-based
//!    identifier remapping** to minimize message-memory slots
//!    (Fig. 7 right);
//! 4. [`loopcomp`] compresses the repetitive section pattern with the
//!    `loop` instruction;
//! 5. [`codegen`] emits the final [`crate::isa::Program`] plus the
//!    [`MemoryMap`] contract the host uses to preload inputs, stream
//!    observations, and read results.
//!
//! ### Streaming observations
//!
//! The paper's RLS example runs one section per received symbol. At 64
//! kbit of message memory (§V) only ~50 message slots exist, so a long
//! chain's observations cannot all be preloaded: the host must stream
//! each section's observation into a fixed slot between loop iterations
//! (the Data-in port of Fig. 5). The compiler therefore maps every
//! message in a *stream group* to one shared slot; this is also what
//! makes consecutive loop bodies bit-identical and hence compressible.

pub mod alloc;
pub mod codegen;
pub mod ir;
pub mod loopcomp;
pub mod lower;

pub use alloc::{AllocOptions, MemoryMap, ScorePolicy};
pub use codegen::{compile, CompileOptions, CompileStats, CompiledProgram};
pub use ir::{LowOp, VOperand};

/// Errors raised during compilation.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CompileError {
    /// The schedule needs more live message slots than the memory has.
    #[error("message memory exceeded: need {needed} slots, have {available}")]
    OutOfMemory { needed: usize, available: usize },
    /// The graph carries more state matrices than state memory holds.
    #[error("state memory exceeded: need {needed} slots, have {available}")]
    OutOfStateMemory { needed: usize, available: usize },
    /// A step consumed a message no earlier step produced.
    #[error("schedule step {step} uses message {msg} before it is defined")]
    UseBeforeDef { step: usize, msg: usize },
    /// The emitted instruction stream exceeds program-memory capacity.
    #[error("program too long for PM: {len} instructions (max {max})")]
    ProgramTooLong { len: usize, max: usize },
}
