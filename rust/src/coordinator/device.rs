//! The Fig. 5 command protocol behind a device thread.
//!
//! §III: "The FGP can be controlled from an external processor via a set
//! of commands. Each command gets replied by a status message." —
//! [`FgpDevice`] runs an [`Fgp`] on its own thread and exposes exactly
//! that request/reply interface over channels, as if the simulator were
//! a memory-mapped co-processor. Used by `examples/fgp_server.rs` and by
//! host-integration tests.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use crate::fgp::processor::{Command, Reply};
use crate::fgp::{Fgp, FgpConfig};

enum DeviceMsg {
    Cmd(Command, Sender<Reply>),
    Stop,
}

/// Handle to a device thread running an FGP.
pub struct FgpDevice {
    tx: Sender<DeviceMsg>,
    handle: Option<JoinHandle<Fgp>>,
}

impl FgpDevice {
    /// Boot the device.
    pub fn start(config: FgpConfig) -> Self {
        let (tx, rx): (Sender<DeviceMsg>, Receiver<DeviceMsg>) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("fgp-device".into())
            .spawn(move || {
                let mut fgp = Fgp::new(config);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        DeviceMsg::Cmd(cmd, reply_tx) => {
                            let reply = fgp.execute_command(cmd);
                            let _ = reply_tx.send(reply);
                        }
                        DeviceMsg::Stop => break,
                    }
                }
                fgp
            })
            .expect("spawn device thread");
        FgpDevice { tx, handle: Some(handle) }
    }

    /// Issue a command and wait for the status reply.
    pub fn command(&self, cmd: Command) -> Reply {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(DeviceMsg::Cmd(cmd, rtx)).is_err() {
            return Reply::Error("device stopped".into());
        }
        rrx.recv().unwrap_or_else(|_| Reply::Error("device died".into()))
    }

    /// Stop the device and recover the simulator (for inspection).
    pub fn stop(mut self) -> Option<Fgp> {
        let _ = self.tx.send(DeviceMsg::Stop);
        self.handle.take().and_then(|h| h.join().ok())
    }
}

impl Drop for FgpDevice {
    fn drop(&mut self) {
        let _ = self.tx.send(DeviceMsg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fgp::processor::FsmState;
    use crate::gmp::message::GaussMessage;

    #[test]
    fn boots_and_replies_to_status() {
        let dev = FgpDevice::start(FgpConfig::default());
        match dev.command(Command::Status) {
            Reply::Status { state, cycles } => {
                assert_eq!(state, FsmState::Idle);
                assert_eq!(cycles, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(dev.stop().is_some());
    }

    #[test]
    fn write_read_roundtrip_through_protocol() {
        let dev = FgpDevice::start(FgpConfig::default());
        let msg = GaussMessage::isotropic(4, 2.0);
        match dev.command(Command::WriteMessage { slot: 3, msg: msg.clone() }) {
            Reply::Ok => {}
            other => panic!("unexpected {other:?}"),
        }
        match dev.command(Command::ReadMessage { slot: 3 }) {
            Reply::Message(m) => assert!(m.dist(&msg) < 1e-2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn bad_commands_reply_errors() {
        let dev = FgpDevice::start(FgpConfig::default());
        match dev.command(Command::StartProgram { id: 42 }) {
            Reply::Error(e) => assert!(e.contains("no program")),
            other => panic!("unexpected {other:?}"),
        }
    }
}
