//! The Fig. 5 command protocol behind a device thread.
//!
//! §III: "The FGP can be controlled from an external processor via a set
//! of commands. Each command gets replied by a status message." —
//! [`FgpDevice`] runs an [`Fgp`] on its own thread and exposes exactly
//! that request/reply interface over channels, as if the simulator were
//! a memory-mapped co-processor. Used by `examples/fgp_server.rs` and by
//! host-integration tests.
//!
//! Protocol failures are **typed** ([`ProtocolError`]), mirroring the
//! serving path's [`super::ServerClosed`]: a dead device thread, an
//! error status from the device, or a reply variant that does not match
//! the issued command all surface as `Err`, never as a panic in the
//! caller's `match` arms.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use crate::fgp::processor::{Command, FsmState, Reply, RunStats};
use crate::fgp::{Fgp, FgpConfig};
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::isa::MemoryImage;

// The typed protocol error lives next to `Command`/`Reply` in
// `fgp::processor` (in-process hosts need the same path); re-exported
// here so `coordinator::ProtocolError` keeps working.
pub use crate::fgp::processor::ProtocolError;

enum DeviceMsg {
    Cmd(Command, Sender<Reply>),
    Stop,
}

/// Handle to a device thread running an FGP.
pub struct FgpDevice {
    tx: Sender<DeviceMsg>,
    handle: Option<JoinHandle<Fgp>>,
}

impl FgpDevice {
    /// Boot the device.
    pub fn start(config: FgpConfig) -> Self {
        let (tx, rx): (Sender<DeviceMsg>, Receiver<DeviceMsg>) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("fgp-device".into())
            .spawn(move || {
                let mut fgp = Fgp::new(config);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        DeviceMsg::Cmd(cmd, reply_tx) => {
                            let reply = fgp.execute_command(cmd);
                            let _ = reply_tx.send(reply);
                        }
                        DeviceMsg::Stop => break,
                    }
                }
                fgp
            })
            .expect("spawn device thread");
        FgpDevice { tx, handle: Some(handle) }
    }

    /// Issue a raw command and wait for the status reply. Channel
    /// failures (the device thread is gone) surface as
    /// [`ProtocolError::DeviceClosed`]; the reply itself is returned
    /// unconverted — use the typed helpers below for `match`-free hosts.
    pub fn command(&self, cmd: Command) -> Result<Reply, ProtocolError> {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(DeviceMsg::Cmd(cmd, rtx)).is_err() {
            return Err(ProtocolError::DeviceClosed);
        }
        rrx.recv().map_err(|_| ProtocolError::DeviceClosed)
    }

    /// Issue a command expecting a specific reply shape (the typed
    /// [`Reply::expect`] projection over the channel).
    fn expect<T>(
        &self,
        cmd: Command,
        name: &'static str,
        pick: impl FnOnce(Reply) -> Result<T, Reply>,
    ) -> Result<T, ProtocolError> {
        self.command(cmd)?.expect(name, pick)
    }

    /// Query the FSM state and lifetime cycle counter.
    pub fn status(&self) -> Result<(FsmState, u64), ProtocolError> {
        self.expect(Command::Status, "Status", |r| match r {
            Reply::Status { state, cycles } => Ok((state, cycles)),
            other => Err(other),
        })
    }

    /// Load a program image into the PM; returns the instruction count.
    pub fn load_program(&self, image: MemoryImage) -> Result<usize, ProtocolError> {
        self.expect(Command::LoadProgram(image), "LoadProgram", |r| match r {
            Reply::Loaded { instrs } => Ok(instrs),
            other => Err(other),
        })
    }

    /// Start program `id` and wait for its run statistics.
    pub fn start_program(&self, id: u8) -> Result<RunStats, ProtocolError> {
        self.expect(Command::StartProgram { id }, "StartProgram", |r| match r {
            Reply::Finished(stats) => Ok(stats),
            other => Err(other),
        })
    }

    /// Write a message into message memory (Data-in port).
    pub fn write_message(&self, slot: u8, msg: GaussMessage) -> Result<(), ProtocolError> {
        self.expect(Command::WriteMessage { slot, msg }, "WriteMessage", |r| match r {
            Reply::Ok => Ok(()),
            other => Err(other),
        })
    }

    /// Write a state matrix (Mem-A port).
    pub fn write_state(&self, slot: u8, a: CMatrix) -> Result<(), ProtocolError> {
        self.expect(Command::WriteState { slot, a }, "WriteState", |r| match r {
            Reply::Ok => Ok(()),
            other => Err(other),
        })
    }

    /// Read a message back (Data-out port).
    pub fn read_message(&self, slot: u8) -> Result<GaussMessage, ProtocolError> {
        self.expect(Command::ReadMessage { slot }, "ReadMessage", |r| match r {
            Reply::Message(m) => Ok(m),
            other => Err(other),
        })
    }

    /// Stop the device and recover the simulator (for inspection).
    pub fn stop(mut self) -> Option<Fgp> {
        let _ = self.tx.send(DeviceMsg::Stop);
        self.handle.take().and_then(|h| h.join().ok())
    }
}

impl Drop for FgpDevice {
    fn drop(&mut self) {
        let _ = self.tx.send(DeviceMsg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boots_and_replies_to_status() {
        let dev = FgpDevice::start(FgpConfig::default());
        let (state, cycles) = dev.status().unwrap();
        assert_eq!(state, FsmState::Idle);
        assert_eq!(cycles, 0);
        assert!(dev.stop().is_some());
    }

    #[test]
    fn write_read_roundtrip_through_protocol() {
        let dev = FgpDevice::start(FgpConfig::default());
        let msg = GaussMessage::isotropic(4, 2.0);
        dev.write_message(3, msg.clone()).unwrap();
        let m = dev.read_message(3).unwrap();
        assert!(m.dist(&msg) < 1e-2);
    }

    #[test]
    fn bad_commands_are_typed_device_errors() {
        let dev = FgpDevice::start(FgpConfig::default());
        match dev.start_program(42) {
            Err(ProtocolError::Device(e)) => assert!(e.contains("no program")),
            other => panic!("expected Device error, got {other:?}"),
        }
        match dev.write_message(200, GaussMessage::isotropic(4, 1.0)) {
            Err(ProtocolError::Device(e)) => assert!(e.contains("out of range")),
            other => panic!("expected Device error, got {other:?}"),
        }
        // the device keeps serving after error replies
        assert!(dev.status().is_ok());
    }

    #[test]
    fn stopped_device_surfaces_device_closed() {
        let mut dev = FgpDevice::start(FgpConfig::default());
        // swap the command channel for one nobody listens on, as if the
        // device thread were gone: every command must error, typed
        let (tx, _rx) = mpsc::channel();
        drop(_rx);
        dev.tx = tx;
        assert_eq!(dev.status(), Err(ProtocolError::DeviceClosed));
        assert_eq!(
            dev.command(Command::Status).unwrap_err(),
            ProtocolError::DeviceClosed
        );
    }

    #[test]
    fn mismatched_reply_is_a_typed_protocol_error() {
        // drive `expect` with a picker that rejects everything: any OK
        // reply must come back as UnexpectedReply, not a panic
        let dev = FgpDevice::start(FgpConfig::default());
        let err = dev
            .expect(Command::Status, "Status", |r| -> Result<(), Reply> { Err(r) })
            .unwrap_err();
        match err {
            ProtocolError::UnexpectedReply { command, reply } => {
                assert_eq!(command, "Status");
                assert!(reply.contains("Status"), "{reply}");
            }
            other => panic!("expected UnexpectedReply, got {other:?}"),
        }
    }
}
