//! Message-update backends the coordinator can route to.
//!
//! | backend   | engine                               | use                |
//! |-----------|--------------------------------------|--------------------|
//! | `Golden`  | f64 node rules (direct solve)        | reference/tests    |
//! | `FgpSim`  | cycle-accurate fixed-point simulator | the paper's device |
//! | `Xla`     | PJRT `cn_update` artifact            | offload, 1/req     |
//! | `XlaBatch`| PJRT `cn_update_batched` artifact    | batched offload    |
//!
//! Every backend serves two request classes through the same
//! [`crate::engine::Session`] machinery:
//!
//! * [`CnRequestData`] — the raw compound-node update (the paper's
//!   Table II benchmark op), kept as a first-class payload because the
//!   batched XLA artifact fuses whole batches of it;
//! * [`WorkloadRequest`] — a full compiled-program execution with
//!   streamed sections: any [`crate::engine::Workload`]'s model shipped
//!   to the serving layer. The CN update is just the smallest instance
//!   ([`WorkloadRequest::cn`]).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::compiler::CompileOptions;
use crate::engine::{bind_streamed, preload_id, Execution, Session, Workload, XlaEngine};
use crate::fgp::{FgpConfig, MsgSlot};
use crate::fixed::{CFix, QFormat};
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::gmp::{nodes, FactorGraph, MsgId, Schedule};
use crate::kernels::{self, CnBatch, CnScratch, CPlanes};
use crate::runtime::RuntimeClient;

/// One compound-node update request payload.
#[derive(Clone, Debug)]
pub struct CnRequestData {
    /// Incoming state message `m_X, V_X`.
    pub x: GaussMessage,
    /// Observation message `m_Y, V_Y`.
    pub y: GaussMessage,
    /// The section's state matrix `A`.
    pub a: CMatrix,
}

/// A generalized serving request: a factor-graph model plus bound inputs,
/// executed as a compiled program with streamed sections on whatever
/// engine the backend drives.
#[derive(Clone, Debug)]
pub struct WorkloadRequest {
    /// The model graph (edges, nodes, state matrices).
    pub graph: FactorGraph,
    /// The message-update schedule to execute.
    pub schedule: Schedule,
    /// A message bound to every schedule input.
    pub inputs: HashMap<MsgId, GaussMessage>,
    /// Compiler options for program engines.
    pub opts: CompileOptions,
    /// Fixed-point format this request must execute under, or `None`
    /// for the executing device's own configured format. A farm device
    /// honours the declared format for exactly this dispatch (width
    /// never silently changes — see `engine::Precision`).
    pub precision: Option<QFormat>,
}

impl WorkloadRequest {
    /// Package any workload's model for the serving layer. The reply is
    /// a raw [`Execution`]; interpret it with the workload's
    /// [`Workload::outcome`].
    pub fn from_workload<W: Workload + ?Sized>(w: &W) -> Result<Self> {
        let (graph, schedule) = w.model()?;
        let inputs = w.inputs(&graph, &schedule)?;
        Ok(WorkloadRequest {
            graph,
            schedule,
            inputs,
            opts: w.compile_options(),
            precision: None,
        })
    }

    /// Declare the fixed-point format this request executes under.
    pub fn with_precision(mut self, fmt: QFormat) -> Self {
        self.precision = Some(fmt);
        self
    }

    /// The canonical single-CN probe shape for dimension `n`: used to
    /// precompile the CN program at backend/farm construction so the
    /// installed cache key matches every later [`WorkloadRequest::cn`].
    pub fn cn_probe(n: usize) -> Result<Self> {
        Self::cn(&CnRequestData {
            x: GaussMessage::isotropic(n, 1.0),
            y: GaussMessage::isotropic(n, 1.0),
            a: CMatrix::identity(n),
        })
    }

    /// The smallest workload: a single compound-observation section.
    pub fn cn(req: &CnRequestData) -> Result<Self> {
        Self::chain(&req.x, &[(req.y.clone(), req.a.clone())])
    }

    /// A compound-observation **chain**: fold `sections` (observation,
    /// state matrix) pairs into `prior` as one compiled-program
    /// execution. This is the serve tier's sticky-stream unit of work —
    /// a chunk of a recursive stream dispatched to one farm device —
    /// and [`WorkloadRequest::cn`] is its single-section instance. The
    /// chain's final state is bitwise identical to folding the sections
    /// one CN update at a time on the same engine (the chunk-invariance
    /// contract pinned by `rust/tests/integration_streaming.rs`), which
    /// is what makes checkpoint/resume at arbitrary chunk boundaries
    /// safe.
    pub fn chain(prior: &GaussMessage, sections: &[(GaussMessage, CMatrix)]) -> Result<Self> {
        if sections.is_empty() {
            bail!("chain request needs at least one section");
        }
        let n = prior.dim();
        let a_list: Vec<CMatrix> = sections.iter().map(|(_, a)| a.clone()).collect();
        let mut graph = FactorGraph::new();
        graph.rls_chain(n, &a_list);
        let schedule = Schedule::forward_sweep(&graph);
        let mut inputs = HashMap::new();
        inputs.insert(preload_id(&graph, &schedule, "msg_prior")?, prior.clone());
        let ys: Vec<GaussMessage> = sections.iter().map(|(y, _)| y.clone()).collect();
        bind_streamed(&graph, &schedule, &ys, &mut inputs)?;
        Ok(WorkloadRequest {
            graph,
            schedule,
            inputs,
            opts: CompileOptions::default(),
            precision: None,
        })
    }
}

/// Which backend a server routes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// f64 golden rules.
    Golden,
    /// Cycle-accurate FGP simulator.
    FgpSim,
    /// PJRT/XLA artifacts, one update per dispatch.
    Xla,
    /// PJRT/XLA batched artifact (`cn_update_batched`).
    XlaBatch,
}

/// A message-update engine behind the serving layer. Batched CN entry
/// point has a default one-at-a-time implementation; `XlaBatch`
/// overrides it. Workload requests execute singly.
///
/// Not `Send`: the PJRT client is thread-affine (`Rc` internally), so
/// backends are constructed *on* the server's worker thread via the
/// factory passed to [`super::CnServer::start`].
pub trait Backend {
    /// Execute one compound-node update.
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage>;

    /// Execute a batch of updates (default: one by one).
    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        reqs.iter().map(|r| self.cn_update(r)).collect()
    }

    /// Execute a general workload request (compiled-program execution
    /// with streamed sections).
    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution>;

    /// Which backend this is (reporting/routing).
    fn kind(&self) -> BackendKind;
}

/// f64 golden rules (direct solve) — the numeric reference.
pub struct GoldenBackend;

impl Backend for GoldenBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        nodes::compound_observation(&req.x, &req.y, &req.a, false).map_err(Into::into)
    }

    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution> {
        if let Some(fmt) = req.precision {
            bail!(
                "golden backend computes in f64 and cannot honour fixed precision q{}.{}",
                fmt.int_bits,
                fmt.frac_bits
            );
        }
        Session::golden()
            .dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts)
            .map(|d| d.exec)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Golden
    }
}

/// The cycle-accurate FGP simulator behind a [`Session`]: the CN program
/// is compiled once at construction (like the silicon preloading its PM)
/// and every further workload shape is compiled on first sight and
/// cached — each request streams its operands into the device slots,
/// starts the program, and reads the result back, exactly the §IV
/// hardware/software interaction.
pub struct FgpSimBackend {
    session: Session,
    config: FgpConfig,
    /// Prebuilt CN model reused across requests on the hot path: only
    /// the state matrix and the two input messages change per request.
    cn_shape: WorkloadRequest,
    /// Virtual ids of the CN shape's prior and observation inputs.
    cn_prior: MsgId,
    cn_obs: MsgId,
    /// Simulated device cycles consumed so far (for throughput reports).
    pub device_cycles: u64,
}

impl FgpSimBackend {
    /// Backend over a fresh simulator session, CN program precompiled.
    pub fn new(config: FgpConfig) -> Result<Self> {
        let mut session = Session::fgp_sim(config);
        // compile the single-CN program up front so construction reports
        // compiler errors (and the first request is already a cache hit)
        let cn_shape = WorkloadRequest::cn_probe(config.n)?;
        session
            .precompile(&cn_shape.graph, &cn_shape.schedule, &cn_shape.opts)
            .context("compiling CN program")?;
        let cn_prior = preload_id(&cn_shape.graph, &cn_shape.schedule, "msg_prior")?;
        let (_, streamed) = crate::engine::split_inputs(&cn_shape.graph, &cn_shape.schedule);
        let cn_obs = streamed
            .first()
            .map(|(mid, _)| *mid)
            .context("CN shape has no streamed observation edge")?;
        Ok(FgpSimBackend { session, config, cn_shape, cn_prior, cn_obs, device_cycles: 0 })
    }

    /// Cycles one CN update costs on the device (timing model).
    pub fn cn_cycles(&self) -> u64 {
        self.config.timing.compound_node_cycles(self.config.n)
    }

    /// Which shape-specialized kernel the batched path dispatches to for
    /// this device's dimension (reported in the throughput bench).
    pub fn kernel_path(&self) -> &'static str {
        kernels::kernel_path(self.config.n)
    }

    /// Program-cache counters of the underlying session.
    pub fn cache_stats(&self) -> crate::engine::CacheStats {
        self.session.cache_stats()
    }
}

impl Backend for FgpSimBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        if req.x.dim() != self.config.n {
            bail!(
                "CN request has n={} but the device is configured for n={}",
                req.x.dim(),
                self.config.n
            );
        }
        // reuse the prebuilt model; only the data changes per request
        self.cn_shape.graph.states[0] = req.a.clone();
        self.cn_shape.inputs.insert(self.cn_prior, req.x.clone());
        self.cn_shape.inputs.insert(self.cn_obs, req.y.clone());
        let d = self.session.dispatch(
            &self.cn_shape.graph,
            &self.cn_shape.schedule,
            &self.cn_shape.inputs,
            &self.cn_shape.opts,
        )?;
        self.device_cycles += d.exec.stats.cycles;
        Ok(d.exec.output()?.clone())
    }

    /// Batched CN updates through the shape-specialized SoA kernels
    /// (`crate::kernels::cn_update_batch`) instead of one interpreted
    /// program run per request. Operands quantize exactly as the device
    /// slot writes do ([`MsgSlot::from_message`] / `CFix::from_f64`), the
    /// kernel replays the compiled CN op sequence on raw planes, and the
    /// readback dequantizes exactly as the device readout does — so the
    /// results are bitwise identical to looping [`Backend::cn_update`]
    /// (pinned by `rust/tests/property_kernels.rs`). Device cycles charge
    /// the multi-PE batch model, which at `n_pes = 1` equals the
    /// sequential per-update cost.
    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        let n = self.config.n;
        if reqs.is_empty() {
            return Vec::new();
        }
        // Any off-shape request falls back to the sequential path, which
        // reports the dimension error per item.
        if reqs.iter().any(|r| {
            r.x.dim() != n || r.y.dim() != n || r.a.rows != n || r.a.cols != n
        }) {
            return reqs.iter().map(|r| self.cn_update(r)).collect();
        }
        let fmt = self.config.fmt;
        let mut batch = CnBatch::new(n);
        let mut qa = Vec::with_capacity(n * n);
        for r in reqs {
            let sx = MsgSlot::from_message(&r.x, fmt);
            let sy = MsgSlot::from_message(&r.y, fmt);
            qa.clear();
            for i in 0..n {
                for j in 0..n {
                    let z = r.a[(i, j)];
                    qa.push(CFix::from_f64(z.re, z.im, fmt));
                }
            }
            batch.push(&sx.v, &sx.m, &sy.v, &sy.m, &qa);
        }
        let mut out_v = CPlanes::default();
        let mut out_m = CPlanes::default();
        let mut scratch = CnScratch::default();
        kernels::cn_update_batch(fmt, &batch, &mut out_v, &mut out_m, &mut scratch);
        self.device_cycles += self.config.multi_pe.batch_cycles(&self.config.timing, n, reqs.len());
        (0..reqs.len())
            .map(|lane| {
                let slot = MsgSlot {
                    v: out_v.slice(lane * n * n..(lane + 1) * n * n).to_cfix(fmt),
                    m: out_m.slice(lane * n..(lane + 1) * n).to_cfix(fmt),
                };
                Ok(slot.to_message(n))
            })
            .collect()
    }

    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution> {
        // honour the request's declared format for exactly this
        // dispatch, then restore the backend's configured width so the
        // CN hot path and the SoA batch kernels stay at `config.fmt`
        self.session.set_fixed_format(req.precision.unwrap_or(self.config.fmt));
        let d = self.session.dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts);
        if req.precision.is_some() {
            self.session.set_fixed_format(self.config.fmt);
        }
        let d = d?;
        self.device_cycles += d.exec.stats.cycles;
        Ok(d.exec)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::FgpSim
    }
}

/// PJRT single-request backend.
pub struct XlaBackend {
    rt: Rc<RuntimeClient>,
    session: Session,
}

impl XlaBackend {
    /// Backend over a PJRT runtime (one update per dispatch).
    pub fn new(rt: RuntimeClient) -> Self {
        let rt = Rc::new(rt);
        let session = Session::new(Box::new(XlaEngine::shared(Rc::clone(&rt))));
        XlaBackend { rt, session }
    }
}

impl Backend for XlaBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        self.rt.cn_update(&req.x, &req.y, &req.a)
    }

    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution> {
        if req.precision.is_some() {
            bail!("XLA backend computes in float and cannot honour fixed precision");
        }
        self.session
            .dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts)
            .map(|d| d.exec)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }
}

/// PJRT batched backend: one artifact dispatch for a whole CN batch.
pub struct XlaBatchBackend {
    rt: Rc<RuntimeClient>,
    session: Session,
    max_batch: usize,
}

impl XlaBatchBackend {
    /// Batched backend over a PJRT runtime (`cn_update_batched`).
    pub fn new(rt: RuntimeClient) -> Result<Self> {
        let max_batch = rt
            .manifest
            .entry("cn_update_batched")
            .and_then(|e| e.batch())
            .context("batched artifact missing")?;
        let rt = Rc::new(rt);
        let session = Session::new(Box::new(XlaEngine::shared(Rc::clone(&rt))));
        Ok(XlaBatchBackend { rt, session, max_batch })
    }

    /// Largest batch the AOT artifact accepts per dispatch.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

impl Backend for XlaBatchBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        let mut out = self.cn_update_batch(std::slice::from_ref(req));
        out.pop().unwrap()
    }

    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        let mut results = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(self.max_batch) {
            let tuples: Vec<(GaussMessage, GaussMessage, CMatrix)> = chunk
                .iter()
                .map(|r| (r.x.clone(), r.y.clone(), r.a.clone()))
                .collect();
            match self.rt.cn_update_batched(&tuples) {
                Ok(outs) => results.extend(outs.into_iter().map(Ok)),
                Err(e) => {
                    let msg = format!("{e:#}");
                    for _ in chunk {
                        results.push(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
        }
        results
    }

    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution> {
        if req.precision.is_some() {
            bail!("XLA backend computes in float and cannot honour fixed precision");
        }
        self.session
            .dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts)
            .map(|d| d.exec)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::XlaBatch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    /// Request within the device's **input-scaling contract** (see
    /// `fgp` module docs): covariances ~0.15-scaled well-conditioned PSD,
    /// |A| entries ≲ 1, means within ±0.5. Within this envelope the
    /// 16-bit datapath tracks f64 to <0.01; outside it the Faddeev
    /// intermediates can hit the Q5.10 saturation rails — faithful
    /// fixed-point behaviour that the host-side block scaling avoids.
    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        use crate::gmp::matrix::c64;
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn golden_backend_works() {
        let mut b = GoldenBackend;
        let mut rng = Rng::new(1);
        let req = request(&mut rng, 4);
        let out = b.cn_update(&req).unwrap();
        assert!(out.trace_cov() <= req.x.trace_cov() + 1e-9);
        assert_eq!(b.kind(), BackendKind::Golden);
    }

    #[test]
    fn fgp_sim_backend_matches_golden() {
        let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut golden = GoldenBackend;
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let req = request(&mut rng, 4);
            let got = sim.cn_update(&req).unwrap();
            let want = golden.cn_update(&req).unwrap();
            let d = got.dist(&want);
            assert!(d < 0.02, "sim vs golden dist {d}");
        }
        assert_eq!(sim.device_cycles, 10 * sim.cn_cycles());
        // the CN program was compiled once (at construction), never again
        let stats = sim.cache_stats();
        assert_eq!((stats.misses, stats.hits), (1, 10));
    }

    #[test]
    fn cn_is_just_the_smallest_workload() {
        let mut rng = Rng::new(7);
        let req = request(&mut rng, 4);
        let wr = WorkloadRequest::cn(&req).unwrap();
        assert_eq!(wr.graph.nodes.len(), 1);
        let exec = GoldenBackend.run_workload(&wr).unwrap();
        let want = GoldenBackend.cn_update(&req).unwrap();
        assert!(exec.output().unwrap().dist(&want) < 1e-12);
    }

    #[test]
    fn chain_matches_sequential_cn_updates() {
        let mut rng = Rng::new(9);
        let prior = GaussMessage::new(
            (0..4)
                .map(|_| crate::gmp::matrix::c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5)))
                .collect(),
            CMatrix::random_psd(&mut rng, 4, 1.0).scale(0.15),
        );
        let sections: Vec<(GaussMessage, CMatrix)> = (0..5)
            .map(|_| {
                let r = request(&mut rng, 4);
                (r.y, r.a)
            })
            .collect();
        let wr = WorkloadRequest::chain(&prior, &sections).unwrap();
        let exec = GoldenBackend.run_workload(&wr).unwrap();
        let mut want = prior.clone();
        for (y, a) in &sections {
            want = GoldenBackend
                .cn_update(&CnRequestData { x: want, y: y.clone(), a: a.clone() })
                .unwrap();
        }
        assert!(exec.output().unwrap().dist(&want) < 1e-12);
        assert!(WorkloadRequest::chain(&prior, &[]).is_err());
    }

    /// The SoA kernel batch path is bitwise-identical to the interpreted
    /// per-request path — both read back through the same quantized slot
    /// encoding, so the f64 messages must compare *exactly* equal.
    #[test]
    fn fgp_sim_batched_kernels_bitwise_match_sequential() {
        let mut seq = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut bat = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut rng = Rng::new(11);
        // 7 requests: exercises a padded tail block (7 -> 8 lanes)
        let reqs: Vec<_> = (0..7).map(|_| request(&mut rng, 4)).collect();
        let want: Vec<GaussMessage> =
            reqs.iter().map(|r| seq.cn_update(r).unwrap()).collect();
        let got = bat.cn_update_batch(&reqs);
        assert_eq!(got.len(), reqs.len());
        for (g, w) in got.iter().zip(&want) {
            let g = g.as_ref().unwrap();
            assert_eq!(g.mean, w.mean, "batched mean must be bitwise equal");
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(g.cov[(i, j)], w.cov[(i, j)], "cov ({i},{j})");
                }
            }
        }
        // at n_pes = 1 the batch charge equals the sequential per-update sum
        assert_eq!(bat.device_cycles, seq.device_cycles);
        assert_eq!(bat.device_cycles, 7 * bat.cn_cycles());
        assert_eq!(bat.kernel_path(), "soa-mono-n4");
    }

    /// Off-shape requests fall back to the per-request path and surface
    /// its dimension error.
    #[test]
    fn fgp_sim_batch_rejects_off_shape_requests() {
        let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut rng = Rng::new(13);
        let reqs = vec![request(&mut rng, 3)];
        let out = sim.cn_update_batch(&reqs);
        assert!(out[0].is_err());
        assert_eq!(sim.device_cycles, 0);
    }

    #[test]
    fn default_batch_is_sequential() {
        let mut b = GoldenBackend;
        let mut rng = Rng::new(3);
        let reqs: Vec<_> = (0..4).map(|_| request(&mut rng, 4)).collect();
        let outs = b.cn_update_batch(&reqs);
        assert_eq!(outs.len(), 4);
        for (o, r) in outs.iter().zip(&reqs) {
            let single = GoldenBackend.cn_update(r).unwrap();
            assert!(o.as_ref().unwrap().dist(&single) < 1e-12);
        }
    }
}

#[cfg(test)]
mod precision_probe {
    use super::*;
    use crate::fixed::QFormat;
    use crate::gmp::matrix::c64;
    use crate::gmp::message::GaussMessage;
    use crate::testutil::Rng;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.25),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.25),
            ),
            a: CMatrix::random(rng, n, n).scale(0.4),
        }
    }

    /// The fixed-point error is a *format* property, not an algorithm
    /// bug: at Q8.20 the simulator agrees with the f64 golden rules to
    /// 1e-4. (E9 sweeps this format axis as a bench.)
    #[test]
    fn wide_format_collapses_quantization_error() {
        let cfg = crate::fgp::FgpConfig { fmt: QFormat::new(8, 20), ..Default::default() };
        let mut sim = FgpSimBackend::new(cfg).unwrap();
        let mut rng = Rng::new(2);
        for i in 0..5 {
            let req = request(&mut rng, 4);
            let got = sim.cn_update(&req).unwrap();
            let want = GoldenBackend.cn_update(&req).unwrap();
            let d = got.dist(&want);
            assert!(d < 1e-3, "case {i}: Q8.20 dist {d}");
        }
    }

    /// A `WorkloadRequest` declaring q8.20 on a q5.10-configured backend
    /// executes at q8.20 (bitwise equal to a q8.20-configured device)
    /// and the backend is restored to its own width afterwards; the f64
    /// reference refuses rather than silently ignoring the declaration.
    #[test]
    fn workload_precision_overrides_and_restores_the_device_format() {
        let wide_fmt = QFormat::new(8, 20);
        let mut base = FgpSimBackend::new(crate::fgp::FgpConfig::default()).unwrap();
        let wide_cfg = crate::fgp::FgpConfig { fmt: wide_fmt, ..Default::default() };
        let mut wide = FgpSimBackend::new(wide_cfg).unwrap();
        let mut rng = Rng::new(21);
        let req = request(&mut rng, 4);
        let wr = WorkloadRequest::cn(&req).unwrap().with_precision(wide_fmt);
        let got = base.run_workload(&wr).unwrap();
        let want = wide.run_workload(&WorkloadRequest::cn(&req).unwrap()).unwrap();
        assert_eq!(
            got.output().unwrap(),
            want.output().unwrap(),
            "declared q8.20 must match a q8.20-configured device bitwise"
        );
        // base is back at its configured width: a plain CN update still
        // matches a fresh default-format backend bitwise
        let mut fresh = FgpSimBackend::new(crate::fgp::FgpConfig::default()).unwrap();
        assert_eq!(base.cn_update(&req).unwrap(), fresh.cn_update(&req).unwrap());
        assert!(GoldenBackend.run_workload(&wr).is_err());
    }

    /// Error decreases monotonically with fraction bits (E9's invariant).
    #[test]
    fn error_monotone_in_fraction_bits() {
        let mut worst = f64::INFINITY;
        for frac in [10u32, 14, 18] {
            let cfg = crate::fgp::FgpConfig {
                fmt: QFormat::new(8, frac),
                ..Default::default()
            };
            let mut sim = FgpSimBackend::new(cfg).unwrap();
            let mut rng = Rng::new(5);
            let mut max_d: f64 = 0.0;
            for _ in 0..3 {
                let req = request(&mut rng, 4);
                let got = sim.cn_update(&req).unwrap();
                let want = GoldenBackend.cn_update(&req).unwrap();
                max_d = max_d.max(got.dist(&want));
            }
            assert!(
                max_d < worst * 1.5,
                "frac {frac}: error {max_d} vs previous {worst}"
            );
            worst = worst.min(max_d);
        }
    }
}
