//! Message-update backends the coordinator can route to.
//!
//! | backend   | engine                               | use                |
//! |-----------|--------------------------------------|--------------------|
//! | `Golden`  | f64 node rules (direct solve)        | reference/tests    |
//! | `FgpSim`  | cycle-accurate fixed-point simulator | the paper's device |
//! | `Xla`     | PJRT `cn_update` artifact            | offload, 1/req     |
//! | `XlaBatch`| PJRT `cn_update_batched` artifact    | batched offload    |

use anyhow::{Context, Result};

use crate::compiler::{compile, CompileOptions, CompiledProgram};
use crate::fgp::processor::NoFeed;
use crate::fgp::{Fgp, FgpConfig};
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::gmp::{nodes, FactorGraph, Schedule};
use crate::runtime::RuntimeClient;

/// One compound-node update request payload.
#[derive(Clone, Debug)]
pub struct CnRequestData {
    pub x: GaussMessage,
    pub y: GaussMessage,
    pub a: CMatrix,
}

/// Which backend a server routes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Golden,
    FgpSim,
    Xla,
    XlaBatch,
}

/// A message-update engine. Batched entry point has a default
/// one-at-a-time implementation; `XlaBatch` overrides it.
///
/// Not `Send`: the PJRT client is thread-affine (`Rc` internally), so
/// backends are constructed *on* the server's worker thread via the
/// factory passed to [`super::CnServer::start`].
pub trait Backend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage>;

    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        reqs.iter().map(|r| self.cn_update(r)).collect()
    }

    fn kind(&self) -> BackendKind;
}

/// f64 golden rules (direct solve) — the numeric reference.
pub struct GoldenBackend;

impl Backend for GoldenBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        nodes::compound_observation(&req.x, &req.y, &req.a, false).map_err(Into::into)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Golden
    }
}

/// The cycle-accurate FGP simulator running a precompiled single-CN
/// program: each request streams its operands into the device slots,
/// starts the program, and reads the result back — exactly the §IV
/// hardware/software interaction.
pub struct FgpSimBackend {
    fgp: Fgp,
    compiled: CompiledProgram,
    /// Simulated device cycles consumed so far (for throughput reports).
    pub device_cycles: u64,
}

impl FgpSimBackend {
    pub fn new(config: FgpConfig) -> Result<Self> {
        let n = config.n;
        // single compound-node graph, compiled once
        let mut g = FactorGraph::new();
        g.rls_chain(n, &[CMatrix::identity(n)]);
        let sched = Schedule::forward_sweep(&g);
        let compiled =
            compile(&g, &sched, &CompileOptions::default()).context("compiling CN program")?;
        let mut fgp = Fgp::new(config);
        fgp.pm
            .load(&compiled.program.to_image())
            .context("loading CN program")?;
        Ok(FgpSimBackend { fgp, compiled, device_cycles: 0 })
    }

    /// Cycles one CN update costs on the device (timing model).
    pub fn cn_cycles(&self) -> u64 {
        self.fgp.config.timing.compound_node_cycles(self.fgp.config.n)
    }
}

impl Backend for FgpSimBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        let map = &self.compiled.memmap;
        let prior_slot = map.preloads[0].1;
        let (_, obs_slot, _) = map.streams[0];
        let (_, state_slot, _) = map.state_streams[0];
        self.fgp.msgmem.write_message(prior_slot, &req.x);
        self.fgp.msgmem.write_message(obs_slot, &req.y);
        self.fgp.statemem.write_matrix(state_slot, &req.a);
        let stats = self.fgp.run_program(1, &mut NoFeed)?;
        self.device_cycles += stats.cycles;
        let out_slot = map.outputs[0].1;
        Ok(self.fgp.msgmem.read_message(out_slot))
    }

    fn kind(&self) -> BackendKind {
        BackendKind::FgpSim
    }
}

/// PJRT single-request backend.
pub struct XlaBackend {
    rt: RuntimeClient,
}

impl XlaBackend {
    pub fn new(rt: RuntimeClient) -> Self {
        XlaBackend { rt }
    }
}

impl Backend for XlaBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        self.rt.cn_update(&req.x, &req.y, &req.a)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }
}

/// PJRT batched backend: one artifact dispatch for a whole batch.
pub struct XlaBatchBackend {
    rt: RuntimeClient,
    max_batch: usize,
}

impl XlaBatchBackend {
    pub fn new(rt: RuntimeClient) -> Result<Self> {
        let max_batch = rt
            .manifest
            .entry("cn_update_batched")
            .and_then(|e| e.batch())
            .context("batched artifact missing")?;
        Ok(XlaBatchBackend { rt, max_batch })
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

impl Backend for XlaBatchBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        let mut out = self.cn_update_batch(std::slice::from_ref(req));
        out.pop().unwrap()
    }

    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        let mut results = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(self.max_batch) {
            let tuples: Vec<(GaussMessage, GaussMessage, CMatrix)> = chunk
                .iter()
                .map(|r| (r.x.clone(), r.y.clone(), r.a.clone()))
                .collect();
            match self.rt.cn_update_batched(&tuples) {
                Ok(outs) => results.extend(outs.into_iter().map(Ok)),
                Err(e) => {
                    let msg = format!("{e:#}");
                    for _ in chunk {
                        results.push(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
        }
        results
    }

    fn kind(&self) -> BackendKind {
        BackendKind::XlaBatch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    /// Request within the device's **input-scaling contract** (see
    /// `fgp` module docs): covariances ~0.15-scaled well-conditioned PSD,
    /// |A| entries ≲ 1, means within ±0.5. Within this envelope the
    /// 16-bit datapath tracks f64 to <0.01; outside it the Faddeev
    /// intermediates can hit the Q5.10 saturation rails — faithful
    /// fixed-point behaviour that the host-side block scaling avoids.
    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        use crate::gmp::matrix::c64;
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn golden_backend_works() {
        let mut b = GoldenBackend;
        let mut rng = Rng::new(1);
        let req = request(&mut rng, 4);
        let out = b.cn_update(&req).unwrap();
        assert!(out.trace_cov() <= req.x.trace_cov() + 1e-9);
        assert_eq!(b.kind(), BackendKind::Golden);
    }

    #[test]
    fn fgp_sim_backend_matches_golden() {
        let mut sim = FgpSimBackend::new(FgpConfig::default()).unwrap();
        let mut golden = GoldenBackend;
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let req = request(&mut rng, 4);
            let got = sim.cn_update(&req).unwrap();
            let want = golden.cn_update(&req).unwrap();
            let d = got.dist(&want);
            assert!(d < 0.02, "sim vs golden dist {d}");
        }
        assert_eq!(sim.device_cycles, 10 * sim.cn_cycles());
    }

    #[test]
    fn default_batch_is_sequential() {
        let mut b = GoldenBackend;
        let mut rng = Rng::new(3);
        let reqs: Vec<_> = (0..4).map(|_| request(&mut rng, 4)).collect();
        let outs = b.cn_update_batch(&reqs);
        assert_eq!(outs.len(), 4);
        for (o, r) in outs.iter().zip(&reqs) {
            let single = GoldenBackend.cn_update(r).unwrap();
            assert!(o.as_ref().unwrap().dist(&single) < 1e-12);
        }
    }
}

#[cfg(test)]
mod precision_probe {
    use super::*;
    use crate::fixed::QFormat;
    use crate::gmp::matrix::c64;
    use crate::gmp::message::GaussMessage;
    use crate::testutil::Rng;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.25),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.25),
            ),
            a: CMatrix::random(rng, n, n).scale(0.4),
        }
    }

    /// The fixed-point error is a *format* property, not an algorithm
    /// bug: at Q8.20 the simulator agrees with the f64 golden rules to
    /// 1e-4. (E9 sweeps this format axis as a bench.)
    #[test]
    fn wide_format_collapses_quantization_error() {
        let cfg = crate::fgp::FgpConfig { fmt: QFormat::new(8, 20), ..Default::default() };
        let mut sim = FgpSimBackend::new(cfg).unwrap();
        let mut rng = Rng::new(2);
        for i in 0..5 {
            let req = request(&mut rng, 4);
            let got = sim.cn_update(&req).unwrap();
            let want = GoldenBackend.cn_update(&req).unwrap();
            let d = got.dist(&want);
            assert!(d < 1e-3, "case {i}: Q8.20 dist {d}");
        }
    }

    /// Error decreases monotonically with fraction bits (E9's invariant).
    #[test]
    fn error_monotone_in_fraction_bits() {
        let mut worst = f64::INFINITY;
        for frac in [10u32, 14, 18] {
            let cfg = crate::fgp::FgpConfig {
                fmt: QFormat::new(8, frac),
                ..Default::default()
            };
            let mut sim = FgpSimBackend::new(cfg).unwrap();
            let mut rng = Rng::new(5);
            let mut max_d: f64 = 0.0;
            for _ in 0..3 {
                let req = request(&mut rng, 4);
                let got = sim.cn_update(&req).unwrap();
                let want = GoldenBackend.cn_update(&req).unwrap();
                max_d = max_d.max(got.dist(&want));
            }
            assert!(
                max_d < worst * 1.5,
                "frac {frac}: error {max_d} vs previous {worst}"
            );
            worst = worst.min(max_d);
        }
    }
}
