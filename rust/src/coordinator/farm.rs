//! Multi-device accelerator farm.
//!
//! §III imagines one FGP attached to a host; a deployment scales out with
//! several. [`FgpFarm`] owns N simulated devices, each behind a
//! [`Session`], and routes **workload requests** (compiled-program
//! executions with streamed sections — the CN update being just the
//! smallest one) by policy:
//!
//! * `RoundRobin` — stateless rotation;
//! * `LeastLoaded` — the device with the fewest simulated cycles consumed
//!   (a proxy for queue depth on real silicon).
//!
//! The CN program is compiled **once** on the control plane and installed
//! into every device session's program cache; new workload shapes compile
//! on first sight per device and are cached from then on. Every device
//! runs on its own thread behind the Fig. 5 command channel, so the farm
//! also exercises the protocol under concurrency.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::compiler::CompileOptions;
use crate::engine::{Execution, Session, StreamBinder, StreamRun, StreamSample, StreamingWorkload};
use crate::fgp::FgpConfig;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;

use super::backend::{CnRequestData, WorkloadRequest};

/// Request routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Stateless rotation over devices.
    RoundRobin,
    /// Route to the device with the fewest simulated cycles.
    LeastLoaded,
}

/// How a device should reply: the full execution, or (for the CN
/// fast path) just the single output message.
enum DeviceResp {
    Exec(Sender<Result<Execution>>),
    Cn(Sender<Result<GaussMessage>>),
}

impl DeviceResp {
    fn send(self, result: Result<Execution>) {
        match self {
            DeviceResp::Exec(tx) => {
                let _ = tx.send(result);
            }
            DeviceResp::Cn(tx) => {
                let _ = tx.send(result.and_then(|exec| Ok(exec.output()?.clone())));
            }
        }
    }
}

struct DeviceMsg {
    req: WorkloadRequest,
    resp: DeviceResp,
}

struct Device {
    tx: Sender<DeviceMsg>,
    /// Simulated device cycles consumed (load proxy).
    cycles: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

/// A farm of simulated FGPs.
pub struct FgpFarm {
    devices: Vec<Device>,
    policy: RoutePolicy,
    next: AtomicUsize,
}

impl FgpFarm {
    /// Boot `count` devices, each with the CN program pre-installed in
    /// its session cache (compiled once, shared via `Arc`).
    pub fn start(count: usize, config: FgpConfig, policy: RoutePolicy) -> Result<Self> {
        if count == 0 {
            return Err(anyhow!("farm needs at least one device"));
        }
        // compile the single-CN program once; every device installs the
        // same Arc instead of recompiling
        let probe = WorkloadRequest::cn_probe(config.n)?;
        let cn_program = {
            let mut control = Session::fgp_sim(config);
            control
                .precompile(&probe.graph, &probe.schedule, &probe.opts)
                .map_err(|e| anyhow!("compiling CN program: {e:#}"))?
        };

        let mut devices = Vec::with_capacity(count);
        for d in 0..count {
            let (tx, rx): (Sender<DeviceMsg>, Receiver<DeviceMsg>) = mpsc::channel();
            let cycles = Arc::new(AtomicU64::new(0));
            let cycles2 = Arc::clone(&cycles);
            let probe2 = probe.clone();
            let program2 = Arc::clone(&cn_program);
            let handle = std::thread::Builder::new()
                .name(format!("fgp-farm-{d}"))
                .spawn(move || {
                    let mut session = Session::fgp_sim(config);
                    session.install(&probe2.graph, &probe2.schedule, &probe2.opts, program2);
                    while let Ok(msg) = rx.recv() {
                        let result = session
                            .dispatch(
                                &msg.req.graph,
                                &msg.req.schedule,
                                &msg.req.inputs,
                                &msg.req.opts,
                            )
                            .map(|d| {
                                cycles2.fetch_add(d.exec.stats.cycles, Ordering::Relaxed);
                                d.exec
                            });
                        msg.resp.send(result);
                    }
                })
                .expect("spawn farm device");
            devices.push(Device { tx, cycles, handle: Some(handle) });
        }
        Ok(FgpFarm { devices, policy, next: AtomicUsize::new(0) })
    }

    /// Number of devices in the farm.
    pub fn size(&self) -> usize {
        self.devices.len()
    }

    /// Pick a device per the routing policy.
    fn route(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.devices.len()
            }
            RoutePolicy::LeastLoaded => (0..self.devices.len())
                .min_by_key(|i| self.devices[*i].cycles.load(Ordering::Relaxed))
                .unwrap(),
        }
    }

    /// Dispatch one workload request; blocks for the reply.
    pub fn run(&self, req: WorkloadRequest) -> Result<Execution> {
        let (rrx, idx) = self.submit_workload(req);
        rrx.recv().map_err(|_| anyhow!("device {idx} died"))?
    }

    /// Dispatch one CN update (the smallest workload); blocks.
    pub fn update(&self, req: CnRequestData) -> Result<GaussMessage> {
        let exec = self.run(WorkloadRequest::cn(&req)?)?;
        Ok(exec.output()?.clone())
    }

    /// Async workload dispatch; returns the reply channel and the device.
    pub fn submit_workload(
        &self,
        req: WorkloadRequest,
    ) -> (Receiver<Result<Execution>>, usize) {
        let idx = self.route();
        (self.submit_to(idx, req), idx)
    }

    /// Async CN dispatch; returns the reply channel and the chosen device.
    /// The device thread unwraps the single output message itself — no
    /// adapter hop on the client side.
    pub fn submit(&self, req: CnRequestData) -> (Receiver<Result<GaussMessage>>, usize) {
        let idx = self.route();
        let (rtx, rrx) = mpsc::channel();
        match WorkloadRequest::cn(&req) {
            Ok(wr) => {
                if let Err(mpsc::SendError(msg)) =
                    self.devices[idx].tx.send(DeviceMsg { req: wr, resp: DeviceResp::Cn(rtx) })
                {
                    msg.resp.send(Err(anyhow!("device {idx} stopped")));
                }
            }
            // request construction failed client-side; the routed device
            // was never reached but the index reflects the routing choice
            Err(e) => {
                let _ = rtx.send(Err(e));
            }
        }
        (rrx, idx)
    }

    /// Per-device simulated cycle counters.
    pub fn load_profile(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.cycles.load(Ordering::Relaxed)).collect()
    }

    /// Submit a workload request to a **specific** device, bypassing the
    /// routing policy (stream stickiness). A bad index or a stopped
    /// device surfaces as an `Err` on the reply channel, the same
    /// error-via-channel contract every async submit here uses.
    pub fn submit_to(&self, idx: usize, req: WorkloadRequest) -> Receiver<Result<Execution>> {
        let (rtx, rrx) = mpsc::channel();
        match self.devices.get(idx) {
            None => {
                let _ = rtx.send(Err(anyhow!(
                    "no device {idx} in a {}-device farm",
                    self.devices.len()
                )));
            }
            Some(d) => {
                if let Err(mpsc::SendError(msg)) =
                    d.tx.send(DeviceMsg { req, resp: DeviceResp::Exec(rtx) })
                {
                    msg.resp.send(Err(anyhow!("device {idx} stopped")));
                }
            }
        }
        rrx
    }

    /// Open a **sticky** stream session over this farm: the routing
    /// policy picks a device once, and every chunk of the stream then
    /// lands on that same device — its session keeps the stream's
    /// compiled chunk program cached and PM-resident, and the client
    /// side carries the recursive state between chunks, so per-device
    /// state persists across samples. Concurrent streams naturally
    /// spread across devices (round-robin assigns them in open order)
    /// and stay **bitwise identical** to a single
    /// [`Session::run_stream`](crate::engine::Session::run_stream) run.
    pub fn open_stream<'f, 'w, W: StreamingWorkload + ?Sized>(
        &'f self,
        w: &'w W,
    ) -> Result<FarmStream<'f, 'w, W>> {
        let device = self.route();
        let chunk = w.max_chunk().max(1);
        let binder = StreamBinder::build(w, chunk)?;
        Ok(FarmStream {
            farm: self,
            w,
            device,
            chunk,
            binder,
            opts: w.stream_compile_options(),
            state: w.initial_state(),
            boundaries: Vec::new(),
            samples: 0,
            cycles: 0,
        })
    }
}

/// A client-side stream pinned to one farm device (see
/// [`FgpFarm::open_stream`]).
pub struct FarmStream<'f, 'w, W: StreamingWorkload + ?Sized> {
    farm: &'f FgpFarm,
    w: &'w W,
    device: usize,
    chunk: usize,
    binder: StreamBinder,
    opts: CompileOptions,
    state: GaussMessage,
    boundaries: Vec<GaussMessage>,
    samples: u64,
    cycles: u64,
}

impl<W: StreamingWorkload + ?Sized> FarmStream<'_, '_, W> {
    /// The pinned device index.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Current recursive state.
    pub fn state(&self) -> &GaussMessage {
        &self.state
    }

    /// Simulated device cycles this stream has consumed.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    fn dispatch(&self, req: WorkloadRequest) -> Result<Execution> {
        let rx = self.farm.submit_to(self.device, req);
        rx.recv().map_err(|_| anyhow!("device {} died", self.device))?
    }

    /// Feed every remaining sample through the pinned device and return
    /// the finished run (interpret it with the workload's
    /// `stream_outcome`). Consumes the stream: one `FarmStream` is one
    /// pass over its workload's sample iterator.
    pub fn run_to_end(mut self) -> Result<StreamRun> {
        loop {
            let mut batch: Vec<StreamSample> = Vec::with_capacity(self.chunk);
            while batch.len() < self.chunk {
                match self.w.next_sample(self.samples as usize + batch.len(), &self.state)? {
                    Some(s) => batch.push(s),
                    None => break,
                }
            }
            let real = batch.len();
            if real == 0 {
                break;
            }
            let exec = if real == self.chunk {
                self.binder.bind(&self.state, &batch)?;
                self.dispatch(WorkloadRequest {
                    graph: self.binder.graph.clone(),
                    schedule: self.binder.schedule.clone(),
                    inputs: self.binder.inputs.clone(),
                    opts: self.opts,
                })?
            } else {
                let mut tail = StreamBinder::build(self.w, real)?;
                tail.bind(&self.state, &batch)?;
                self.dispatch(WorkloadRequest {
                    graph: tail.graph,
                    schedule: tail.schedule,
                    inputs: tail.inputs,
                    opts: self.opts,
                })?
            };
            self.state = exec.output()?.clone();
            self.boundaries.push(self.state.clone());
            self.cycles += exec.stats.cycles;
            self.samples += real as u64;
            if real < self.chunk {
                break;
            }
        }
        Ok(StreamRun {
            final_state: self.state,
            boundaries: self.boundaries,
            samples: self.samples,
        })
    }
}

impl Drop for FgpFarm {
    fn drop(&mut self) {
        for d in &mut self.devices {
            // closing the channel stops the thread
            let (dummy, _) = mpsc::channel();
            d.tx = dummy;
            if let Some(h) = d.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::c64;
    use crate::testutil::Rng;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn farm_serves_correct_results() {
        let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..9 {
            let req = request(&mut rng, 4);
            let got = farm.update(req.clone()).unwrap();
            let want =
                crate::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, true).unwrap();
            assert!(got.dist(&want) < 0.05, "dist {}", got.dist(&want));
        }
    }

    #[test]
    fn round_robin_balances_evenly() {
        let farm = FgpFarm::start(4, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(2);
        let pending: Vec<_> = (0..16).map(|_| farm.submit(request(&mut rng, 4))).collect();
        let mut per_dev = [0usize; 4];
        for (rx, idx) in pending {
            rx.recv().unwrap().unwrap();
            per_dev[idx] += 1;
        }
        assert_eq!(per_dev, [4, 4, 4, 4]);
        let loads = farm.load_profile();
        assert!(loads.iter().all(|c| *c == loads[0]), "{loads:?}");
    }

    #[test]
    fn least_loaded_fills_idle_devices() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::LeastLoaded).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        let loads = farm.load_profile();
        // synchronous updates + least-loaded -> perfectly alternating
        assert_eq!(loads[0], loads[1], "{loads:?}");
    }

    #[test]
    fn farm_survives_concurrent_clients() {
        let farm =
            Arc::new(FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let farm = Arc::clone(&farm);
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(10 + t);
                for _ in 0..8 {
                    farm.update(request(&mut rng, 4)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let total: u64 = farm.load_profile().iter().sum();
        let cn = FgpConfig::default().timing.compound_node_cycles(4);
        assert_eq!(total, cn * 32);
    }

    #[test]
    fn farm_runs_chain_workloads() {
        use crate::apps::rls::RlsProblem;
        use crate::engine::Workload;

        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let p = RlsProblem::synthetic(4, 8, 0.02, 17);
        let exec = farm.run(WorkloadRequest::from_workload(&p).unwrap()).unwrap();
        let outcome = p.outcome(&exec).unwrap();
        assert!(outcome.rel_mse.is_finite(), "rel MSE {}", outcome.rel_mse);
        assert_eq!(exec.stats.sections, 8);
    }
}
