//! Multi-device accelerator farm with kill/revive membership.
//!
//! §III imagines one FGP attached to a host; a deployment scales out with
//! several. [`FgpFarm`] owns N simulated devices, each behind a
//! [`Session`], and routes **workload requests** (compiled-program
//! executions with streamed sections — the CN update being just the
//! smallest one) by policy:
//!
//! * `RoundRobin` — stateless rotation over the **live** members;
//! * `LeastLoaded` — the live device with the fewest simulated cycles
//!   consumed (a proxy for queue depth on real silicon).
//!
//! The CN program is compiled **once** on the control plane and installed
//! into every device session's program cache; new workload shapes compile
//! on first sight per device and are cached from then on. Every device
//! runs on its own thread behind the Fig. 5 command channel, so the farm
//! also exercises the protocol under concurrency.
//!
//! ## Membership and typed failure (the serve tier's substrate)
//!
//! Each device slot is an `RwLock<Option<DeviceLink>>`:
//! [`FgpFarm::kill_device`] takes the link down (the thread finishes its
//! in-flight request, then exits — no sample is ever half-executed) and
//! [`FgpFarm::revive_device`] respawns it with the stored CN program.
//! Submitting to a dead, missing, or lock-poisoned device never panics;
//! it surfaces a typed [`FarmError`] on the reply channel, and
//! [`FarmError::is_retryable`] tells callers — the serve tier's engine
//! room above all — whether re-dispatching the same work to another
//! member is sound. Retrying is lossless because nothing advances a
//! stream's accounting until an execution actually returns.
//!
//! ## Sticky streams, checkpoints, failover
//!
//! [`FgpFarm::open_stream`] pins a recursive stream to one device so its
//! compiled chunk program stays cached and PM-resident.
//! [`FarmStream::step_chunk`] advances one chunk at a time;
//! [`FarmStream::checkpoint`] snapshots the per-sample state
//! ([`StreamCheckpoint`]) and [`FgpFarm::resume_stream`] restores it on
//! any member — bitwise identically, by the chunk-invariance contract
//! documented on [`StreamCheckpoint`].
//!
//! ## Per-device health (the routing signal)
//!
//! With [`FgpFarm::enable_health_tracking`] on, every device thread
//! keeps an EWMA of its request latency next to request/error
//! counters; [`FgpFarm::device_health`] scores each member against the
//! live-peer median ([`device_score`]) and [`FgpFarm::pick_healthy`]
//! filters picks by that score, falling back to the plain policy pick
//! when nothing qualifies — the serving tier drains sticky streams off
//! degraded-but-alive members through it. Off (the default) the device
//! loop reads no clocks at all: the invariant-7 extension.
//! [`FgpFarm::set_device_delay`] is the matching fault injector — a
//! per-request sleep that degrades a member without killing it.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::compiler::{CompileOptions, CompiledProgram};
use crate::engine::{
    Execution, Session, StreamBinder, StreamCheckpoint, StreamRun, StreamSample,
    StreamingWorkload,
};
use crate::fgp::FgpConfig;
use crate::fixed::QFormat;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::obs::health::{device_score, DeviceHealth};
use crate::obs::{Telemetry, TelemetryConfig, TraceContext};

use super::backend::{Backend, BackendKind, CnRequestData, WorkloadRequest};

/// Request routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Stateless rotation over live devices.
    RoundRobin,
    /// Route to the live device with the fewest simulated cycles.
    LeastLoaded,
}

/// Typed farm failures — everything a submitter can observe going wrong
/// on the device plane, as data. Wrapped in `anyhow::Error` on the
/// reply channels (`err.downcast_ref::<FarmError>()` recovers the typed
/// value), so the serve tier can distinguish *retry elsewhere* from
/// *give up*.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum FarmError {
    /// The device index is outside the farm (a caller bug — not
    /// retryable, no other member would change the answer).
    #[error("no device {device} in a {size}-device farm")]
    NoSuchDevice {
        /// The requested index.
        device: usize,
        /// Farm size.
        size: usize,
    },
    /// The device was killed (or died) before the request executed.
    /// Retryable: the request never ran, so re-submitting it to a live
    /// member neither loses nor duplicates work.
    #[error("device {device} stopped")]
    DeviceStopped {
        /// The dead device.
        device: usize,
    },
    /// The device slot's lock is poisoned (a thread panicked while
    /// holding it). Retryable on another member; [`FgpFarm::kill_device`]
    /// + [`FgpFarm::revive_device`] clear the poison and recover the slot.
    #[error("device {device} lock poisoned")]
    DevicePoisoned {
        /// The poisoned device.
        device: usize,
    },
    /// Every device in the farm is down.
    #[error("all {size} farm devices are down")]
    AllDevicesDown {
        /// Farm size.
        size: usize,
    },
}

impl FarmError {
    /// Whether re-submitting the same request to another live member is
    /// sound (the request was never executed).
    pub fn is_retryable(&self) -> bool {
        !matches!(self, FarmError::NoSuchDevice { .. })
    }
}

/// How a device should reply: the full execution, or (for the CN
/// fast path) just the single output message.
enum DeviceResp {
    Exec(Sender<Result<Execution>>),
    Cn(Sender<Result<GaussMessage>>),
}

impl DeviceResp {
    fn send(self, result: Result<Execution>) {
        match self {
            DeviceResp::Exec(tx) => {
                let _ = tx.send(result);
            }
            DeviceResp::Cn(tx) => {
                let _ = tx.send(result.and_then(|exec| Ok(exec.output()?.clone())));
            }
        }
    }
}

struct DeviceMsg {
    req: WorkloadRequest,
    resp: DeviceResp,
    /// Parent span for this request's device-side work (`None` when the
    /// caller is untraced — the common in-process path).
    ctx: Option<TraceContext>,
}

/// A live device: its command channel and thread handle.
struct DeviceLink {
    tx: Sender<DeviceMsg>,
    handle: JoinHandle<()>,
}

/// Per-device stats shared between the farm (reader) and the device
/// thread (writer); `Arc`'d so they survive kill/revive.
#[derive(Clone)]
struct DeviceStats {
    /// Simulated device cycles consumed (load proxy; survives revive).
    cycles: Arc<AtomicU64>,
    /// Requests executed successfully.
    requests: Arc<AtomicU64>,
    /// Failed requests: dispatch errors plus dead/poisoned routing.
    errors: Arc<AtomicU64>,
    /// EWMA request latency in ns, 0 until the first health-tracked
    /// sample. Single writer (the device thread), so plain
    /// load/modify/store is race-free.
    ewma_ns: Arc<AtomicU64>,
    /// Fault injection: per-request sleep in ms (0 = none).
    delay_ms: Arc<AtomicU64>,
}

impl DeviceStats {
    fn new() -> Self {
        DeviceStats {
            cycles: Arc::new(AtomicU64::new(0)),
            requests: Arc::new(AtomicU64::new(0)),
            errors: Arc::new(AtomicU64::new(0)),
            ewma_ns: Arc::new(AtomicU64::new(0)),
            delay_ms: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// One device slot; `None` while the member is down.
struct DeviceSlot {
    link: RwLock<Option<DeviceLink>>,
    stats: DeviceStats,
}

/// A farm of simulated FGPs.
pub struct FgpFarm {
    devices: Vec<DeviceSlot>,
    policy: RoutePolicy,
    next: AtomicUsize,
    config: FgpConfig,
    /// The CN probe shape + its compiled program, kept so a revived
    /// device re-installs the same cache entry the boot devices got.
    probe: WorkloadRequest,
    cn_program: Arc<CompiledProgram>,
    /// Shared telemetry handle every device session reports into (a
    /// disabled default unless [`FgpFarm::start_with_telemetry`] was
    /// used); revived devices re-attach it.
    tel: Arc<Telemetry>,
    /// Health-tracking switch, shared with the device threads. Off ⇒
    /// the device loop reads no clocks (invariant-7 extension).
    health_on: Arc<AtomicBool>,
}

fn spawn_device(
    d: usize,
    config: FgpConfig,
    probe: WorkloadRequest,
    program: Arc<CompiledProgram>,
    stats: DeviceStats,
    tel: Arc<Telemetry>,
    health_on: Arc<AtomicBool>,
    rx: Receiver<DeviceMsg>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("fgp-farm-{d}"))
        .spawn(move || {
            let mut session = Session::fgp_sim(config);
            session.set_telemetry(Arc::clone(&tel));
            session.install(&probe.graph, &probe.schedule, &probe.opts, program);
            // a kill drops the sender: the loop finishes the request it
            // already received (its reply still reaches the client),
            // then exits — queued-but-unreceived requests are dropped,
            // which the submitter observes as a retryable DeviceStopped
            while let Ok(msg) = rx.recv() {
                // fault injection: a degraded-but-alive member
                let delay = stats.delay_ms.load(Ordering::Relaxed);
                if delay > 0 {
                    std::thread::sleep(Duration::from_millis(delay));
                }
                // traced requests get a "farm.device" span; the session
                // hangs its engine/fgp spans underneath it
                let dev_ctx = match msg.ctx {
                    Some(ctx) if tel.enabled() => Some((ctx.child(), ctx.span_id)),
                    _ => None,
                };
                session.set_trace_context(dev_ctx.map(|(c, _)| c));
                let t0 = if dev_ctx.is_some() { tel.now_ns() } else { 0 };
                // honour the request's declared fixed-point format for
                // exactly this dispatch; a request without one executes
                // at the farm's configured width, so a previous
                // request's format never leaks (width never silently
                // changes — the precision contract)
                session.set_fixed_format(msg.req.precision.unwrap_or(config.fmt));
                // latency EWMA only when health tracking is on: the
                // disabled path must read no clocks (invariant 7 ext.)
                let h0 = health_on.load(Ordering::Relaxed).then(Instant::now);
                let result = session
                    .dispatch(&msg.req.graph, &msg.req.schedule, &msg.req.inputs, &msg.req.opts)
                    .map(|disp| {
                        stats.cycles.fetch_add(disp.exec.stats.cycles, Ordering::Relaxed);
                        disp.exec
                    });
                // drain this thread's datapath saturation events into
                // the shared registry: counting is always on and never
                // changes results (invariant-7 safe), so production
                // overflow is observable over the Stats/Health wire
                let sats = crate::fixed::raw::take_saturations();
                if sats > 0 {
                    tel.registry().add("fixed.saturations", sats);
                }
                if let Some(h0) = h0 {
                    let sample = h0.elapsed().as_nanos() as u64;
                    let old = stats.ewma_ns.load(Ordering::Relaxed);
                    let next = if old == 0 { sample } else { old - old / 8 + sample / 8 };
                    stats.ewma_ns.store(next, Ordering::Relaxed);
                }
                match &result {
                    Ok(_) => stats.requests.fetch_add(1, Ordering::Relaxed),
                    Err(_) => stats.errors.fetch_add(1, Ordering::Relaxed),
                };
                if let Some((child, parent)) = dev_ctx {
                    tel.span(child, parent, "farm.device", "farm", t0, d as u64);
                    session.set_trace_context(None);
                }
                msg.resp.send(result);
            }
        })
        .expect("spawn farm device")
}

impl FgpFarm {
    /// Boot `count` devices, each with the CN program pre-installed in
    /// its session cache (compiled once, shared via `Arc`). Telemetry is
    /// off; see [`FgpFarm::start_with_telemetry`].
    pub fn start(count: usize, config: FgpConfig, policy: RoutePolicy) -> Result<Self> {
        Self::start_with_telemetry(
            count,
            config,
            policy,
            Arc::new(Telemetry::new(TelemetryConfig::default())),
        )
    }

    /// [`FgpFarm::start`] with a shared [`Telemetry`] handle: device
    /// sessions feed its registry counters, and traced submits
    /// (`*_traced`) hang per-device span trees under the caller's
    /// context. With `tel` disabled this is exactly `start`.
    pub fn start_with_telemetry(
        count: usize,
        config: FgpConfig,
        policy: RoutePolicy,
        tel: Arc<Telemetry>,
    ) -> Result<Self> {
        if count == 0 {
            return Err(anyhow!("farm needs at least one device"));
        }
        // compile the single-CN program once; every device installs the
        // same Arc instead of recompiling
        let probe = WorkloadRequest::cn_probe(config.n)?;
        let cn_program = {
            let mut control = Session::fgp_sim(config);
            control
                .precompile(&probe.graph, &probe.schedule, &probe.opts)
                .map_err(|e| anyhow!("compiling CN program: {e:#}"))?
        };

        let health_on = Arc::new(AtomicBool::new(false));
        let mut devices = Vec::with_capacity(count);
        for d in 0..count {
            let (tx, rx) = mpsc::channel();
            let stats = DeviceStats::new();
            let handle = spawn_device(
                d,
                config,
                probe.clone(),
                Arc::clone(&cn_program),
                stats.clone(),
                Arc::clone(&tel),
                Arc::clone(&health_on),
                rx,
            );
            devices.push(DeviceSlot {
                link: RwLock::new(Some(DeviceLink { tx, handle })),
                stats,
            });
        }
        Ok(FgpFarm {
            devices,
            policy,
            next: AtomicUsize::new(0),
            config,
            probe,
            cn_program,
            tel,
            health_on,
        })
    }

    /// The farm's shared telemetry handle.
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.tel
    }

    /// Number of device slots in the farm (live or not).
    pub fn size(&self) -> usize {
        self.devices.len()
    }

    /// Indices of the currently live devices.
    pub fn live_devices(&self) -> Vec<usize> {
        (0..self.devices.len())
            .filter(|i| {
                matches!(self.devices[*i].link.read().as_deref(), Ok(Some(_)))
            })
            .collect()
    }

    /// Kill device `idx`: drop its command channel (the thread finishes
    /// its in-flight request, then exits) and join the thread. Clears a
    /// poisoned slot lock on the way. Returns `true` if the device was
    /// live. Idempotent.
    pub fn kill_device(&self, idx: usize) -> Result<bool, FarmError> {
        let slot = self
            .devices
            .get(idx)
            .ok_or(FarmError::NoSuchDevice { device: idx, size: self.devices.len() })?;
        let link = {
            let mut guard = match slot.link.write() {
                Ok(g) => g,
                Err(e) => {
                    slot.link.clear_poison();
                    e.into_inner()
                }
            };
            guard.take()
        };
        match link {
            Some(l) => {
                drop(l.tx);
                let _ = l.handle.join();
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Revive device `idx` with the farm's stored CN program. The slot's
    /// cycle counter persists across kill/revive so `LeastLoaded`
    /// routing stays meaningful. Returns `true` if a new thread was
    /// spawned (`false` if the device was already live).
    pub fn revive_device(&self, idx: usize) -> Result<bool, FarmError> {
        let slot = self
            .devices
            .get(idx)
            .ok_or(FarmError::NoSuchDevice { device: idx, size: self.devices.len() })?;
        let mut guard = match slot.link.write() {
            Ok(g) => g,
            Err(e) => {
                slot.link.clear_poison();
                e.into_inner()
            }
        };
        if guard.is_some() {
            return Ok(false);
        }
        let (tx, rx) = mpsc::channel();
        let handle = spawn_device(
            idx,
            self.config,
            self.probe.clone(),
            Arc::clone(&self.cn_program),
            slot.stats.clone(),
            Arc::clone(&self.tel),
            Arc::clone(&self.health_on),
            rx,
        );
        *guard = Some(DeviceLink { tx, handle });
        Ok(true)
    }

    /// Pick a live device per the routing policy, skipping `exclude`
    /// (failover: "anywhere but where it just died").
    pub fn pick(&self, exclude: &[usize]) -> Result<usize, FarmError> {
        let live: Vec<usize> =
            self.live_devices().into_iter().filter(|i| !exclude.contains(i)).collect();
        if live.is_empty() {
            return Err(FarmError::AllDevicesDown { size: self.devices.len() });
        }
        Ok(match self.policy {
            RoutePolicy::RoundRobin => live[self.next.fetch_add(1, Ordering::Relaxed) % live.len()],
            RoutePolicy::LeastLoaded => *live
                .iter()
                .min_by_key(|i| self.devices[**i].stats.cycles.load(Ordering::Relaxed))
                .expect("non-empty live list"),
        })
    }

    /// Turn on per-device latency tracking: the device threads start
    /// reading the clock around each request to keep an EWMA. Off by
    /// default (the invariant-7 extension: disabled health ⇒ no clock
    /// reads on the device plane). One-way for the farm's lifetime.
    pub fn enable_health_tracking(&self) {
        self.health_on.store(true, Ordering::Relaxed);
    }

    /// Is per-device latency tracking on?
    pub fn health_tracking(&self) -> bool {
        self.health_on.load(Ordering::Relaxed)
    }

    /// Fault injection for tests and the health bench: every request to
    /// device `idx` sleeps `millis` before executing (0 clears). The
    /// device stays live and correct — just slow — which is exactly the
    /// degradation the health layer exists to detect.
    pub fn set_device_delay(&self, idx: usize, millis: u64) -> Result<(), FarmError> {
        let slot = self
            .devices
            .get(idx)
            .ok_or(FarmError::NoSuchDevice { device: idx, size: self.devices.len() })?;
        slot.stats.delay_ms.store(millis, Ordering::Relaxed);
        Ok(())
    }

    /// Per-device health: liveness, request/error counts, EWMA latency,
    /// and the routing [`device_score`] against the live-peer median.
    pub fn device_health(&self) -> Vec<DeviceHealth> {
        let live = self.live_devices();
        let median = median_ns(
            self.devices
                .iter()
                .enumerate()
                .filter(|(i, d)| live.contains(i) && d.stats.ewma_ns.load(Ordering::Relaxed) > 0)
                .map(|(_, d)| d.stats.ewma_ns.load(Ordering::Relaxed))
                .collect(),
        );
        self.devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let is_live = live.contains(&i);
                let requests = d.stats.requests.load(Ordering::Relaxed);
                let errors = d.stats.errors.load(Ordering::Relaxed);
                let ewma_ns = d.stats.ewma_ns.load(Ordering::Relaxed);
                DeviceHealth {
                    device: i as u32,
                    live: is_live,
                    requests,
                    errors,
                    ewma_ns,
                    score: device_score(is_live, requests, errors, ewma_ns, median),
                }
            })
            .collect()
    }

    /// [`FgpFarm::pick`] filtered by health score: only members scoring
    /// at least `min_score` qualify. Falls back to the plain policy pick
    /// when health tracking is off, `min_score` is non-positive, or no
    /// member qualifies — a degraded device still beats refusing the
    /// request outright.
    pub fn pick_healthy(&self, exclude: &[usize], min_score: f64) -> Result<usize, FarmError> {
        if min_score <= 0.0 || !self.health_on.load(Ordering::Relaxed) {
            return self.pick(exclude);
        }
        let qualified: Vec<usize> = self
            .device_health()
            .iter()
            .filter(|h| {
                h.live && h.score >= min_score && !exclude.contains(&(h.device as usize))
            })
            .map(|h| h.device as usize)
            .collect();
        if qualified.is_empty() {
            return self.pick(exclude);
        }
        Ok(match self.policy {
            RoutePolicy::RoundRobin => {
                qualified[self.next.fetch_add(1, Ordering::Relaxed) % qualified.len()]
            }
            RoutePolicy::LeastLoaded => *qualified
                .iter()
                .min_by_key(|i| self.devices[**i].stats.cycles.load(Ordering::Relaxed))
                .expect("non-empty qualified list"),
        })
    }

    /// Dispatch one workload request; blocks for the reply.
    pub fn run(&self, req: WorkloadRequest) -> Result<Execution> {
        self.run_traced(req, None)
    }

    /// [`FgpFarm::run`] carrying a parent [`TraceContext`] so the device
    /// records its span tree under the caller's request.
    pub fn run_traced(&self, req: WorkloadRequest, ctx: Option<TraceContext>) -> Result<Execution> {
        let (rrx, idx) = self.submit_workload_traced(req, ctx);
        recv_exec(&rrx, idx)
    }

    /// Dispatch one CN update (the smallest workload); blocks.
    pub fn update(&self, req: CnRequestData) -> Result<GaussMessage> {
        let exec = self.run(WorkloadRequest::cn(&req)?)?;
        Ok(exec.output()?.clone())
    }

    /// Async workload dispatch; returns the reply channel and the device.
    /// If no device is live, the channel carries
    /// [`FarmError::AllDevicesDown`] and the index is 0.
    pub fn submit_workload(
        &self,
        req: WorkloadRequest,
    ) -> (Receiver<Result<Execution>>, usize) {
        self.submit_workload_traced(req, None)
    }

    /// [`FgpFarm::submit_workload`] with an optional parent trace context.
    pub fn submit_workload_traced(
        &self,
        req: WorkloadRequest,
        ctx: Option<TraceContext>,
    ) -> (Receiver<Result<Execution>>, usize) {
        match self.pick(&[]) {
            Ok(idx) => (self.submit_to_traced(idx, req, ctx), idx),
            Err(e) => {
                let (rtx, rrx) = mpsc::channel();
                let _ = rtx.send(Err(e.into()));
                (rrx, 0)
            }
        }
    }

    /// Async CN dispatch; returns the reply channel and the chosen device.
    /// The device thread unwraps the single output message itself — no
    /// adapter hop on the client side.
    pub fn submit(&self, req: CnRequestData) -> (Receiver<Result<GaussMessage>>, usize) {
        self.submit_cn(req, None)
    }

    /// [`FgpFarm::submit`] with a declared fixed-point format: the
    /// routed device executes this update at `precision` (its own
    /// configured width when `None`).
    pub fn submit_cn(
        &self,
        req: CnRequestData,
        precision: Option<QFormat>,
    ) -> (Receiver<Result<GaussMessage>>, usize) {
        let (rtx, rrx) = mpsc::channel();
        let idx = match self.pick(&[]) {
            Ok(i) => i,
            Err(e) => {
                let _ = rtx.send(Err(e.into()));
                return (rrx, 0);
            }
        };
        match WorkloadRequest::cn(&req) {
            Ok(mut wr) => {
                wr.precision = precision;
                self.send_msg(idx, DeviceMsg { req: wr, resp: DeviceResp::Cn(rtx), ctx: None })
            }
            // request construction failed client-side; the routed device
            // was never reached but the index reflects the routing choice
            Err(e) => {
                let _ = rtx.send(Err(e));
            }
        }
        (rrx, idx)
    }

    /// Per-device simulated cycle counters.
    pub fn load_profile(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.stats.cycles.load(Ordering::Relaxed)).collect()
    }

    /// Route `msg` to device `idx`'s channel, converting every failure
    /// mode — bad index, poisoned slot lock, dead thread — into a typed
    /// [`FarmError`] on the reply channel. Never panics (the fix for the
    /// poisoned-lock panic the serving tier inherited).
    fn send_msg(&self, idx: usize, msg: DeviceMsg) {
        let slot = match self.devices.get(idx) {
            Some(s) => s,
            None => {
                msg.resp.send(Err(FarmError::NoSuchDevice {
                    device: idx,
                    size: self.devices.len(),
                }
                .into()));
                return;
            }
        };
        let guard = match slot.link.read() {
            Ok(g) => g,
            Err(_) => {
                slot.stats.errors.fetch_add(1, Ordering::Relaxed);
                msg.resp.send(Err(FarmError::DevicePoisoned { device: idx }.into()));
                return;
            }
        };
        match guard.as_ref() {
            None => {
                slot.stats.errors.fetch_add(1, Ordering::Relaxed);
                msg.resp.send(Err(FarmError::DeviceStopped { device: idx }.into()));
            }
            Some(link) => {
                if let Err(mpsc::SendError(m)) = link.tx.send(msg) {
                    slot.stats.errors.fetch_add(1, Ordering::Relaxed);
                    m.resp.send(Err(FarmError::DeviceStopped { device: idx }.into()));
                }
            }
        }
    }

    /// Submit a workload request to a **specific** device, bypassing the
    /// routing policy (stream stickiness). A bad index, a stopped device
    /// or a poisoned slot lock surfaces as a typed [`FarmError`] on the
    /// reply channel — the same error-via-channel contract every async
    /// submit here uses.
    pub fn submit_to(&self, idx: usize, req: WorkloadRequest) -> Receiver<Result<Execution>> {
        self.submit_to_traced(idx, req, None)
    }

    /// [`FgpFarm::submit_to`] carrying a parent [`TraceContext`]: the
    /// device thread records a `farm.device` span under it and hands the
    /// context down into its session's engine/device spans.
    pub fn submit_to_traced(
        &self,
        idx: usize,
        req: WorkloadRequest,
        ctx: Option<TraceContext>,
    ) -> Receiver<Result<Execution>> {
        let (rtx, rrx) = mpsc::channel();
        self.send_msg(idx, DeviceMsg { req, resp: DeviceResp::Exec(rtx), ctx });
        rrx
    }

    /// Open a **sticky** stream session over this farm: the routing
    /// policy picks a live device once, and every chunk of the stream
    /// then lands on that same device — its session keeps the stream's
    /// compiled chunk program cached and PM-resident, and the client
    /// side carries the recursive state between chunks, so per-device
    /// state persists across samples. Concurrent streams naturally
    /// spread across devices (round-robin assigns them in open order)
    /// and stay **bitwise identical** to a single
    /// [`Session::run_stream`](crate::engine::Session::run_stream) run.
    pub fn open_stream<'f, 'w, W: StreamingWorkload + ?Sized>(
        &'f self,
        w: &'w W,
    ) -> Result<FarmStream<'f, 'w, W>> {
        let device = self.pick(&[])?;
        let chunk = w.max_chunk().max(1);
        let binder = StreamBinder::build(w, chunk)?;
        Ok(FarmStream {
            farm: self,
            w,
            device,
            chunk,
            binder,
            opts: w.stream_compile_options(),
            precision: None,
            state: w.initial_state(),
            boundaries: Vec::new(),
            samples: 0,
            cycles: 0,
        })
    }

    /// Restore a checkpointed stream onto `device` (or let the routing
    /// policy pick a live member). The resumed stream's remaining
    /// outputs are bitwise identical to the uninterrupted run's — the
    /// failover conformance contract (see [`StreamCheckpoint`]).
    pub fn resume_stream<'f, 'w, W: StreamingWorkload + ?Sized>(
        &'f self,
        w: &'w W,
        ckpt: &StreamCheckpoint,
        device: Option<usize>,
    ) -> Result<FarmStream<'f, 'w, W>> {
        if ckpt.stream_name != w.stream_name() {
            bail!(
                "checkpoint belongs to stream '{}' but the workload is '{}'",
                ckpt.stream_name,
                w.stream_name()
            );
        }
        let device = match device {
            Some(d) => {
                if d >= self.devices.len() {
                    return Err(
                        FarmError::NoSuchDevice { device: d, size: self.devices.len() }.into()
                    );
                }
                d
            }
            None => self.pick(&[])?,
        };
        let chunk = w.max_chunk().max(1);
        let binder = StreamBinder::build(w, chunk)?;
        Ok(FarmStream {
            farm: self,
            w,
            device,
            chunk,
            binder,
            opts: w.stream_compile_options(),
            precision: None,
            state: ckpt.state.clone(),
            boundaries: ckpt.boundaries.clone(),
            samples: ckpt.samples,
            cycles: 0,
        })
    }
}

/// Lower-median of the live EWMA latencies: for an even count this
/// takes the lower middle, so in a two-device farm the slow member is
/// judged against the fast one (not against itself) and still drains.
fn median_ns(mut v: Vec<u64>) -> u64 {
    if v.is_empty() {
        return 0;
    }
    v.sort_unstable();
    v[(v.len() - 1) / 2]
}

/// Await an async submit's reply, mapping a dropped reply channel (the
/// device died with the request still queued) to the retryable
/// [`FarmError::DeviceStopped`].
pub fn recv_exec<T>(rx: &Receiver<Result<T>>, device: usize) -> Result<T> {
    match rx.recv() {
        Ok(r) => r,
        Err(_) => Err(FarmError::DeviceStopped { device }.into()),
    }
}

/// A [`Backend`] adapter over a shared farm: CN updates fan out across
/// the live members (batches dispatch concurrently, one request per
/// device pick). This is what lets the serve tier drive the
/// [`super::StreamCoalescer`] against a farm instead of a single
/// in-thread engine.
pub struct FarmCnBackend {
    farm: Arc<FgpFarm>,
    /// Declared fixed-point format every dispatch through this adapter
    /// carries (`None` = each device's configured width). A request's
    /// own declaration wins over the adapter's.
    precision: Option<QFormat>,
}

impl FarmCnBackend {
    /// Adapter over a shared farm.
    pub fn new(farm: Arc<FgpFarm>) -> Self {
        FarmCnBackend { farm, precision: None }
    }

    /// Adapter whose every dispatch declares `fmt` — the serve tier's
    /// coalesced drain uses one per precision group.
    pub fn with_precision(farm: Arc<FgpFarm>, fmt: QFormat) -> Self {
        FarmCnBackend { farm, precision: Some(fmt) }
    }
}

impl Backend for FarmCnBackend {
    fn cn_update(&mut self, req: &CnRequestData) -> Result<GaussMessage> {
        let (rx, idx) = self.farm.submit_cn(req.clone(), self.precision);
        recv_exec(&rx, idx)
    }

    fn cn_update_batch(&mut self, reqs: &[CnRequestData]) -> Vec<Result<GaussMessage>> {
        // submit everything async first, then collect: the batch runs
        // concurrently across however many devices routing spread it over
        let pending: Vec<_> = reqs
            .iter()
            .map(|r| self.farm.submit_cn(r.clone(), self.precision))
            .collect();
        pending.into_iter().map(|(rx, idx)| recv_exec(&rx, idx)).collect()
    }

    fn run_workload(&mut self, req: &WorkloadRequest) -> Result<Execution> {
        let mut req = req.clone();
        if req.precision.is_none() {
            req.precision = self.precision;
        }
        self.farm.run(req)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::FgpSim
    }
}

/// A client-side stream pinned to one farm device (see
/// [`FgpFarm::open_stream`]).
pub struct FarmStream<'f, 'w, W: StreamingWorkload + ?Sized> {
    farm: &'f FgpFarm,
    w: &'w W,
    device: usize,
    chunk: usize,
    binder: StreamBinder,
    opts: CompileOptions,
    /// Declared fixed-point format every chunk dispatch carries (`None`
    /// = the pinned device's configured width). Survives failover and
    /// checkpoint/resume untouched: re-declare it on the resumed
    /// stream — precision is part of the stream's *session*, not the
    /// checkpoint image.
    precision: Option<QFormat>,
    state: GaussMessage,
    boundaries: Vec<GaussMessage>,
    samples: u64,
    cycles: u64,
}

impl<W: StreamingWorkload + ?Sized> FarmStream<'_, '_, W> {
    /// Declare the fixed-point format every chunk of this stream
    /// executes under on the pinned device (and any failover target).
    pub fn with_precision(mut self, fmt: QFormat) -> Self {
        self.precision = Some(fmt);
        self
    }

    /// The stream's declared fixed-point format, if any.
    pub fn precision(&self) -> Option<QFormat> {
        self.precision
    }

    /// The pinned device index.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Current recursive state.
    pub fn state(&self) -> &GaussMessage {
        &self.state
    }

    /// Samples folded into the state so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Simulated device cycles this stream has consumed.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Re-pin the stream to `device` (failover). State, sample cursor
    /// and boundaries carry over untouched; the target device compiles
    /// (or cache-hits) the chunk program on the next dispatch.
    pub fn failover_to(&mut self, device: usize) -> Result<(), FarmError> {
        if device >= self.farm.size() {
            return Err(FarmError::NoSuchDevice { device, size: self.farm.size() });
        }
        self.device = device;
        Ok(())
    }

    /// Failover per the routing policy, excluding the current (failed)
    /// device. Returns the new pin.
    pub fn failover(&mut self) -> Result<usize, FarmError> {
        let device = self.farm.pick(&[self.device])?;
        self.device = device;
        Ok(device)
    }

    /// Snapshot the stream's resumable state (see
    /// [`FgpFarm::resume_stream`] and the wire codec's checkpoint frame).
    pub fn checkpoint(&self) -> StreamCheckpoint {
        StreamCheckpoint {
            stream_name: self.w.stream_name().to_string(),
            samples: self.samples,
            state: self.state.clone(),
            boundaries: self.boundaries.clone(),
        }
    }

    fn dispatch(&self, req: WorkloadRequest) -> Result<Execution> {
        let rx = self.farm.submit_to(self.device, req);
        recv_exec(&rx, self.device)
    }

    /// Advance the stream by one chunk: pull up to `chunk` samples from
    /// the workload, execute them on the pinned device, fold the result
    /// into the recursive state. Returns the samples consumed, or `None`
    /// at end of stream.
    ///
    /// On `Err` **nothing advances**: the sample cursor, state and
    /// boundaries are untouched, so after a
    /// [`failover`](FarmStream::failover) the retry re-pulls exactly the
    /// same samples (`StreamingWorkload::next_sample` is deterministic
    /// in `k`) and the stream neither loses nor duplicates work — the
    /// invariant the churn soak test pins.
    pub fn step_chunk(&mut self) -> Result<Option<u64>> {
        let mut batch: Vec<StreamSample> = Vec::with_capacity(self.chunk);
        while batch.len() < self.chunk {
            match self.w.next_sample(self.samples as usize + batch.len(), &self.state)? {
                Some(s) => batch.push(s),
                None => break,
            }
        }
        let real = batch.len();
        if real == 0 {
            return Ok(None);
        }
        let exec = if real == self.chunk {
            self.binder.bind(&self.state, &batch)?;
            self.dispatch(WorkloadRequest {
                graph: self.binder.graph.clone(),
                schedule: self.binder.schedule.clone(),
                inputs: self.binder.inputs.clone(),
                opts: self.opts,
                precision: self.precision,
            })?
        } else {
            let mut tail = StreamBinder::build(self.w, real)?;
            tail.bind(&self.state, &batch)?;
            self.dispatch(WorkloadRequest {
                graph: tail.graph,
                schedule: tail.schedule,
                inputs: tail.inputs,
                opts: self.opts,
                precision: self.precision,
            })?
        };
        self.state = exec.output()?.clone();
        self.boundaries.push(self.state.clone());
        self.cycles += exec.stats.cycles;
        self.samples += real as u64;
        Ok(Some(real as u64))
    }

    /// Feed every remaining sample through the pinned device and return
    /// the finished run (interpret it with the workload's
    /// `stream_outcome`). Consumes the stream: one `FarmStream` is one
    /// pass over its workload's sample iterator.
    pub fn run_to_end(mut self) -> Result<StreamRun> {
        while let Some(n) = self.step_chunk()? {
            // a short chunk is the stream's tail: stop without probing
            // the sample iterator past the end again
            if (n as usize) < self.chunk {
                break;
            }
        }
        Ok(StreamRun {
            final_state: self.state,
            boundaries: self.boundaries,
            samples: self.samples,
        })
    }
}

impl Drop for FgpFarm {
    fn drop(&mut self) {
        for d in 0..self.devices.len() {
            let _ = self.kill_device(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::c64;
    use crate::testutil::Rng;
    use anyhow::Result;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn farm_serves_correct_results() {
        let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..9 {
            let req = request(&mut rng, 4);
            let got = farm.update(req.clone()).unwrap();
            let want =
                crate::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, true).unwrap();
            assert!(got.dist(&want) < 0.05, "dist {}", got.dist(&want));
        }
    }

    #[test]
    fn round_robin_balances_evenly() {
        let farm = FgpFarm::start(4, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(2);
        let pending: Vec<_> = (0..16).map(|_| farm.submit(request(&mut rng, 4))).collect();
        let mut per_dev = [0usize; 4];
        for (rx, idx) in pending {
            rx.recv().unwrap().unwrap();
            per_dev[idx] += 1;
        }
        assert_eq!(per_dev, [4, 4, 4, 4]);
        let loads = farm.load_profile();
        assert!(loads.iter().all(|c| *c == loads[0]), "{loads:?}");
    }

    #[test]
    fn least_loaded_fills_idle_devices() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::LeastLoaded).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        let loads = farm.load_profile();
        // synchronous updates + least-loaded -> perfectly alternating
        assert_eq!(loads[0], loads[1], "{loads:?}");
    }

    #[test]
    fn farm_survives_concurrent_clients() {
        let farm =
            Arc::new(FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let farm = Arc::clone(&farm);
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(10 + t);
                for _ in 0..8 {
                    farm.update(request(&mut rng, 4)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let total: u64 = farm.load_profile().iter().sum();
        let cn = FgpConfig::default().timing.compound_node_cycles(4);
        assert_eq!(total, cn * 32);
    }

    #[test]
    fn farm_runs_chain_workloads() {
        use crate::apps::rls::RlsProblem;
        use crate::engine::Workload;

        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let p = RlsProblem::synthetic(4, 8, 0.02, 17);
        let exec = farm.run(WorkloadRequest::from_workload(&p).unwrap()).unwrap();
        let outcome = p.outcome(&exec).unwrap();
        assert!(outcome.rel_mse.is_finite(), "rel MSE {}", outcome.rel_mse);
        assert_eq!(exec.stats.sections, 8);
    }

    fn farm_err(r: Result<Execution>) -> FarmError {
        let err = r.unwrap_err();
        err.downcast_ref::<FarmError>()
            .unwrap_or_else(|| panic!("want FarmError in the chain, got {err:#}"))
            .clone()
    }

    #[test]
    fn submit_to_dead_device_is_typed_and_retryable() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        assert!(farm.kill_device(1).unwrap());
        assert!(!farm.kill_device(1).unwrap(), "second kill is a no-op");
        assert_eq!(farm.live_devices(), vec![0]);
        let mut rng = Rng::new(4);
        let req = WorkloadRequest::cn(&request(&mut rng, 4)).unwrap();
        let e = farm_err(recv_exec(&farm.submit_to(1, req.clone()), 1));
        assert_eq!(e, FarmError::DeviceStopped { device: 1 });
        assert!(e.is_retryable());
        // out-of-range index is typed too, but NOT retryable
        let e = farm_err(recv_exec(&farm.submit_to(9, req.clone()), 9));
        assert_eq!(e, FarmError::NoSuchDevice { device: 9, size: 2 });
        assert!(!e.is_retryable());
        // routed traffic avoids the dead member entirely
        for _ in 0..4 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        assert_eq!(farm.load_profile()[1], 0);
        // revive: the member takes traffic again with its cache reseeded
        assert!(farm.revive_device(1).unwrap());
        assert!(!farm.revive_device(1).unwrap(), "second revive is a no-op");
        let (rx, _) = farm.submit(request(&mut rng, 4));
        rx.recv().unwrap().unwrap();
    }

    #[test]
    fn all_devices_down_is_typed() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::LeastLoaded).unwrap();
        farm.kill_device(0).unwrap();
        farm.kill_device(1).unwrap();
        assert_eq!(farm.pick(&[]), Err(FarmError::AllDevicesDown { size: 2 }));
        let mut rng = Rng::new(5);
        let (rx, _) = farm.submit(request(&mut rng, 4));
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(
            err.downcast_ref::<FarmError>(),
            Some(&FarmError::AllDevicesDown { size: 2 })
        );
        // a revive brings the farm back
        farm.revive_device(0).unwrap();
        farm.update(request(&mut rng, 4)).unwrap();
    }

    #[test]
    fn poisoned_device_lock_is_typed_not_a_panic() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        // poison device 0's slot lock deterministically
        let slot_lock = &farm.devices[0].link;
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _guard = slot_lock.write().unwrap();
                panic!("poisoning device lock for the test");
            });
            assert!(h.join().is_err());
        });
        let mut rng = Rng::new(6);
        let req = WorkloadRequest::cn(&request(&mut rng, 4)).unwrap();
        let e = farm_err(recv_exec(&farm.submit_to(0, req), 0));
        assert_eq!(e, FarmError::DevicePoisoned { device: 0 });
        assert!(e.is_retryable());
        // routing skips the poisoned slot; kill + revive recovers it
        for _ in 0..2 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        assert_eq!(farm.load_profile()[0], 0);
        farm.kill_device(0).unwrap();
        farm.revive_device(0).unwrap();
        rx_ok(farm.submit_to(0, WorkloadRequest::cn(&request(&mut rng, 4)).unwrap()));
    }

    fn rx_ok(rx: mpsc::Receiver<Result<Execution>>) {
        rx.recv().unwrap().unwrap();
    }

    /// Cap a streaming workload's chunk so farm streams span several
    /// dispatches (the default RLS chunk of 64 would swallow a short
    /// test stream whole).
    struct ChunkCapped<'a> {
        inner: &'a crate::apps::rls::RlsProblem,
        cap: usize,
    }

    impl StreamingWorkload for ChunkCapped<'_> {
        type StreamOutcome = StreamRun;

        fn stream_name(&self) -> &str {
            self.inner.stream_name()
        }

        fn state_dim(&self) -> usize {
            self.inner.state_dim()
        }

        fn stream_model(&self, chunk: usize) -> Result<(crate::gmp::FactorGraph, crate::gmp::Schedule)> {
            self.inner.stream_model(chunk)
        }

        fn initial_state(&self) -> GaussMessage {
            self.inner.initial_state()
        }

        fn next_sample(&self, k: usize, state: &GaussMessage) -> Result<Option<StreamSample>> {
            self.inner.next_sample(k, state)
        }

        fn max_chunk(&self) -> usize {
            self.cap
        }

        fn stream_outcome(&self, run: &StreamRun) -> Result<StreamRun> {
            Ok(run.clone())
        }
    }

    #[test]
    fn checkpointed_stream_fails_over_bitwise_identically() {
        use crate::apps::rls::RlsProblem;

        let p = RlsProblem::synthetic(4, 16, 0.01, 23);
        let capped = ChunkCapped { inner: &p, cap: 4 };

        // uninterrupted reference run
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let reference = farm.open_stream(&capped).unwrap().run_to_end().unwrap();
        assert_eq!(reference.samples, 16);

        // interrupted run: two chunks, checkpoint, kill the pinned
        // device mid-stream, resume from the checkpoint on another
        // member — then the next dispatch after a live failover too
        let farm2 = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut s = farm2.open_stream(&capped).unwrap();
        let dev0 = s.device();
        assert_eq!(s.step_chunk().unwrap(), Some(4));
        assert_eq!(s.step_chunk().unwrap(), Some(4));
        let ckpt = s.checkpoint();
        assert_eq!(ckpt.samples, 8);
        farm2.kill_device(dev0).unwrap();

        // the in-place path: the stream observes the typed failure and
        // fails over, losing and duplicating nothing
        let err = s.step_chunk().unwrap_err();
        assert!(err.downcast_ref::<FarmError>().unwrap().is_retryable());
        assert_eq!(s.samples(), 8, "failed chunk must not advance the cursor");
        let new_dev = s.failover().unwrap();
        assert_ne!(new_dev, dev0);
        let live = s.run_to_end().unwrap();
        assert_eq!(live.samples, 16);
        assert_eq!(live.final_state, reference.final_state, "live failover diverged");

        // the checkpoint/restore path on a third farm: bitwise again
        let farm3 = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let resumed =
            farm3.resume_stream(&capped, &ckpt, Some(1)).unwrap().run_to_end().unwrap();
        assert_eq!(resumed.samples, 16);
        assert_eq!(resumed.final_state, reference.final_state, "resume diverged");
        assert_eq!(resumed.boundaries.len(), reference.boundaries.len());
        for (a, b) in resumed.boundaries.iter().zip(&reference.boundaries) {
            assert_eq!(a, b, "boundary trace diverged");
        }
        // a checkpoint from the wrong stream is rejected
        let bad = StreamCheckpoint { stream_name: "other".into(), ..ckpt.clone() };
        assert!(farm3.resume_stream(&capped, &bad, None).is_err());
    }

    /// The tentpole's farm leg: a stream declaring q8.20 on a
    /// q5.10-configured farm is bitwise identical to a q8.20-configured
    /// single-device session — across members, across a mid-stream
    /// failover, across checkpoint/resume, and with default-width
    /// traffic interleaved on the same device (no width leaks either
    /// direction).
    #[test]
    fn declared_precision_stream_is_bitwise_across_members_and_failover() {
        use crate::apps::rls::RlsProblem;

        let p = RlsProblem::synthetic(4, 16, 0.01, 31);
        let capped = ChunkCapped { inner: &p, cap: 4 };
        let fmt = QFormat::new(8, 20);

        // reference: a single q8.20-configured device session
        let reference = Session::fgp_sim(FgpConfig { fmt, ..Default::default() })
            .run_stream(&capped)
            .unwrap();

        // a default-width farm, stream declared at q8.20
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let run =
            farm.open_stream(&capped).unwrap().with_precision(fmt).run_to_end().unwrap();
        assert_eq!(run.final_state, reference.final_state, "declared width diverged");

        // kill the pin mid-stream: failover keeps the declared width
        let farm2 = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut s = farm2.open_stream(&capped).unwrap().with_precision(fmt);
        assert_eq!(s.precision(), Some(fmt));
        assert_eq!(s.step_chunk().unwrap(), Some(4));
        assert_eq!(s.step_chunk().unwrap(), Some(4));
        let ckpt = s.checkpoint();
        let dev0 = s.device();
        farm2.kill_device(dev0).unwrap();
        assert!(s.step_chunk().is_err());
        s.failover().unwrap();
        let live = s.run_to_end().unwrap();
        assert_eq!(live.final_state, reference.final_state, "failover diverged");

        // checkpoint/resume on a fresh farm, precision re-declared
        let farm3 = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let resumed = farm3
            .resume_stream(&capped, &ckpt, None)
            .unwrap()
            .with_precision(fmt)
            .run_to_end()
            .unwrap();
        assert_eq!(resumed.final_state, reference.final_state, "resume diverged");

        // default-width requests interleaved on a single-device farm:
        // the device must restore its own width between dispatches
        let farm4 = FgpFarm::start(1, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut s = farm4.open_stream(&capped).unwrap().with_precision(fmt);
        let mut rng = Rng::new(41);
        let baseline = farm4.update(request(&mut rng, 4)).unwrap();
        let mut rng = Rng::new(41);
        while let Some(n) = s.step_chunk().unwrap() {
            let got = farm4.update(request(&mut rng, 4)).unwrap();
            if s.samples() == 4 {
                assert_eq!(got, baseline, "interleaved q5.10 traffic changed width");
            }
            if (n as usize) < 4 {
                break;
            }
        }
        assert_eq!(s.state(), &reference.final_state, "interleaving leaked a width");
    }

    /// `fixed.saturations` observability: a clean wide-format run
    /// reports zero; rail-adjacent operands at a narrow format count
    /// events into the shared registry.
    #[test]
    fn saturations_flow_to_the_registry_and_clean_runs_report_zero() {
        // clean: q8.20 + the well-conditioned test envelope
        let tel = Arc::new(Telemetry::new(TelemetryConfig::default()));
        let wide = FgpConfig { fmt: QFormat::new(8, 20), ..Default::default() };
        let farm =
            FgpFarm::start_with_telemetry(2, wide, RoutePolicy::RoundRobin, tel).unwrap();
        let mut rng = Rng::new(12);
        for _ in 0..4 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        let snap = farm.telemetry().registry().snapshot();
        assert_eq!(
            snap.counter("fixed.saturations").unwrap_or(0),
            0,
            "clean run must report zero saturations"
        );

        // q1.14 rails at ±2: products of rail-adjacent means/entries
        // (1.9 × 1.9 ≈ 3.6) must clamp and be counted
        let tel = Arc::new(Telemetry::new(TelemetryConfig::default()));
        let narrow = FgpConfig { fmt: QFormat::new(1, 14), ..Default::default() };
        let farm =
            FgpFarm::start_with_telemetry(1, narrow, RoutePolicy::RoundRobin, tel).unwrap();
        let hot = CnRequestData {
            x: GaussMessage::new(
                (0..4).map(|_| c64::new(1.9, 0.0)).collect(),
                CMatrix::identity(4).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..4).map(|_| c64::new(1.9, 0.0)).collect(),
                CMatrix::identity(4).scale(0.15),
            ),
            a: CMatrix::identity(4).scale(1.9),
        };
        farm.update(hot).unwrap();
        let snap = farm.telemetry().registry().snapshot();
        assert!(
            snap.counter("fixed.saturations").unwrap_or(0) > 0,
            "railed operands must be counted"
        );
    }

    #[test]
    fn farm_cn_backend_coalesces_against_live_members() {
        use super::super::batcher::{CnStream, StreamCoalescer};

        let farm =
            Arc::new(FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap());
        let mut rng = Rng::new(8);
        let mut streams: Vec<CnStream> = Vec::new();
        let mut expect: Vec<GaussMessage> = Vec::new();
        for _ in 0..3 {
            let r0 = request(&mut rng, 4);
            let mut s = CnStream::new(r0.x.clone());
            let mut want = r0.x.clone();
            for _ in 0..4 {
                let r = request(&mut rng, 4);
                s.push(r.y.clone(), r.a.clone());
                want = farm
                    .update(CnRequestData { x: want, y: r.y, a: r.a })
                    .unwrap();
            }
            streams.push(s);
            expect.push(want);
        }
        // kill a member mid-setup: the adapter only routes to live ones
        farm.kill_device(2).unwrap();
        let mut backend = FarmCnBackend::new(Arc::clone(&farm));
        let total = StreamCoalescer::drain(&mut backend, &mut streams).unwrap();
        assert_eq!(total, 12);
        for (s, want) in streams.iter().zip(&expect) {
            // same device semantics -> bitwise identical fold
            assert_eq!(&s.state, want);
        }
    }

    #[test]
    fn health_tracking_scores_and_pick_healthy_drains_slow_members() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(7);
        // health off (the default): the device loop reads no clocks, so
        // no EWMA accumulates no matter how much traffic runs
        for _ in 0..4 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        assert!(!farm.health_tracking());
        assert!(farm.device_health().iter().all(|h| h.ewma_ns == 0));

        farm.enable_health_tracking();
        farm.set_device_delay(1, 3).unwrap();
        assert!(farm.set_device_delay(9, 3).is_err(), "bad index is typed");
        for _ in 0..8 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        let health = farm.device_health();
        assert!(health[0].ewma_ns > 0, "{health:?}");
        assert!(health[1].ewma_ns > health[0].ewma_ns, "{health:?}");
        assert_eq!(health[0].score, 1.0, "fast member keeps a perfect score: {health:?}");
        // a 3 ms injected delay vs a microsecond-scale peer: the slow
        // member's score collapses below the default drain threshold
        assert!(health[1].score < 0.5, "{health:?}");
        for _ in 0..4 {
            assert_eq!(farm.pick_healthy(&[], 0.5).unwrap(), 0);
        }
        // nothing qualifies at an impossible threshold: plain-pick fallback
        assert!(farm.pick_healthy(&[], 2.0).is_ok());
        // dead members report !live and score 0
        farm.kill_device(1).unwrap();
        let health = farm.device_health();
        assert!(!health[1].live);
        assert_eq!(health[1].score, 0.0);
        assert_eq!(farm.pick_healthy(&[], 0.5).unwrap(), 0);
    }
}
