//! Multi-device accelerator farm.
//!
//! §III imagines one FGP attached to a host; a deployment scales out with
//! several. [`FgpFarm`] owns N simulated devices, each with the CN
//! program resident, and routes requests by policy:
//!
//! * `RoundRobin` — stateless rotation;
//! * `LeastLoaded` — the device with the fewest simulated cycles consumed
//!   (a proxy for queue depth on real silicon).
//!
//! Every device runs on its own thread behind the Fig. 5 command channel,
//! so the farm also exercises the protocol under concurrency.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::compiler::{compile, CompileOptions};
use crate::fgp::processor::NoFeed;
use crate::fgp::{Fgp, FgpConfig};
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::gmp::{FactorGraph, Schedule};

use super::backend::CnRequestData;

/// Request routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

struct DeviceMsg {
    req: CnRequestData,
    resp: Sender<Result<GaussMessage>>,
}

struct Device {
    tx: Sender<DeviceMsg>,
    /// Simulated device cycles consumed (load proxy).
    cycles: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

/// A farm of simulated FGPs.
pub struct FgpFarm {
    devices: Vec<Device>,
    policy: RoutePolicy,
    next: AtomicUsize,
}

impl FgpFarm {
    /// Boot `count` devices, each preloaded with the CN program.
    pub fn start(count: usize, config: FgpConfig, policy: RoutePolicy) -> Result<Self> {
        assert!(count > 0);
        // compile the single-CN program once; each device loads a copy
        let n = config.n;
        let mut g = FactorGraph::new();
        g.rls_chain(n, &[CMatrix::identity(n)]);
        let sched = Schedule::forward_sweep(&g);
        let compiled = compile(&g, &sched, &CompileOptions::default())
            .map_err(|e| anyhow!("compiling CN program: {e}"))?;

        let mut devices = Vec::with_capacity(count);
        for d in 0..count {
            let (tx, rx): (Sender<DeviceMsg>, Receiver<DeviceMsg>) = mpsc::channel();
            let cycles = Arc::new(AtomicU64::new(0));
            let cycles2 = Arc::clone(&cycles);
            let compiled2 = compiled.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fgp-farm-{d}"))
                .spawn(move || {
                    let mut fgp = Fgp::new(config);
                    fgp.pm
                        .load(&compiled2.program.to_image())
                        .expect("CN program loads");
                    let prior_slot = compiled2.memmap.preloads[0].1;
                    let obs_slot = compiled2.memmap.streams[0].1;
                    let st_slot = compiled2.memmap.state_streams[0].1;
                    let out_slot = compiled2.memmap.outputs[0].1;
                    while let Ok(msg) = rx.recv() {
                        fgp.msgmem.write_message(prior_slot, &msg.req.x);
                        fgp.msgmem.write_message(obs_slot, &msg.req.y);
                        fgp.statemem.write_matrix(st_slot, &msg.req.a);
                        let result = fgp
                            .run_program(1, &mut NoFeed)
                            .map(|stats| {
                                cycles2.fetch_add(stats.cycles, Ordering::Relaxed);
                                fgp.msgmem.read_message(out_slot)
                            })
                            .map_err(|e| anyhow!("{e}"));
                        let _ = msg.resp.send(result);
                    }
                })
                .expect("spawn farm device");
            devices.push(Device { tx, cycles, handle: Some(handle) });
        }
        Ok(FgpFarm { devices, policy, next: AtomicUsize::new(0) })
    }

    pub fn size(&self) -> usize {
        self.devices.len()
    }

    /// Pick a device per the routing policy.
    fn route(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.devices.len()
            }
            RoutePolicy::LeastLoaded => (0..self.devices.len())
                .min_by_key(|i| self.devices[*i].cycles.load(Ordering::Relaxed))
                .unwrap(),
        }
    }

    /// Dispatch one CN update; blocks for the reply.
    pub fn update(&self, req: CnRequestData) -> Result<GaussMessage> {
        let idx = self.route();
        let (rtx, rrx) = mpsc::channel();
        self.devices[idx]
            .tx
            .send(DeviceMsg { req, resp: rtx })
            .map_err(|_| anyhow!("device {idx} stopped"))?;
        rrx.recv().map_err(|_| anyhow!("device {idx} died"))?
    }

    /// Async dispatch; returns the reply channel and the chosen device.
    pub fn submit(&self, req: CnRequestData) -> (Receiver<Result<GaussMessage>>, usize) {
        let idx = self.route();
        let (rtx, rrx) = mpsc::channel();
        let _ = self.devices[idx].tx.send(DeviceMsg { req, resp: rtx });
        (rrx, idx)
    }

    /// Per-device simulated cycle counters.
    pub fn load_profile(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.cycles.load(Ordering::Relaxed)).collect()
    }
}

impl Drop for FgpFarm {
    fn drop(&mut self) {
        for d in &mut self.devices {
            // closing the channel stops the thread
            let (dummy, _) = mpsc::channel();
            d.tx = dummy;
            if let Some(h) = d.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::c64;
    use crate::testutil::Rng;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, n, 1.0).scale(0.15),
            ),
            a: CMatrix::random(rng, n, n).scale(0.3),
        }
    }

    #[test]
    fn farm_serves_correct_results() {
        let farm = FgpFarm::start(3, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..9 {
            let req = request(&mut rng, 4);
            let got = farm.update(req.clone()).unwrap();
            let want =
                crate::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, true).unwrap();
            assert!(got.dist(&want) < 0.05, "dist {}", got.dist(&want));
        }
    }

    #[test]
    fn round_robin_balances_evenly() {
        let farm = FgpFarm::start(4, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap();
        let mut rng = Rng::new(2);
        let pending: Vec<_> = (0..16).map(|_| farm.submit(request(&mut rng, 4))).collect();
        let mut per_dev = [0usize; 4];
        for (rx, idx) in pending {
            rx.recv().unwrap().unwrap();
            per_dev[idx] += 1;
        }
        assert_eq!(per_dev, [4, 4, 4, 4]);
        let loads = farm.load_profile();
        assert!(loads.iter().all(|c| *c == loads[0]), "{loads:?}");
    }

    #[test]
    fn least_loaded_fills_idle_devices() {
        let farm = FgpFarm::start(2, FgpConfig::default(), RoutePolicy::LeastLoaded).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            farm.update(request(&mut rng, 4)).unwrap();
        }
        let loads = farm.load_profile();
        // synchronous updates + least-loaded -> perfectly alternating
        assert_eq!(loads[0], loads[1], "{loads:?}");
    }

    #[test]
    fn farm_survives_concurrent_clients() {
        let farm =
            Arc::new(FgpFarm::start(2, FgpConfig::default(), RoutePolicy::RoundRobin).unwrap());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let farm = Arc::clone(&farm);
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(10 + t);
                for _ in 0..8 {
                    farm.update(request(&mut rng, 4)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let total: u64 = farm.load_profile().iter().sum();
        let cn = FgpConfig::default().timing.compound_node_cycles(4);
        assert_eq!(total, cn * 32);
    }
}
