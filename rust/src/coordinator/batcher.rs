//! Dynamic batching (the serving-side half of the offload path).
//!
//! Classic max-batch / max-wait policy: a batch is dispatched as soon as
//! it reaches `max_batch` requests, or when the oldest queued request has
//! waited `max_wait`, whichever comes first. With the PJRT batched
//! artifact, one dispatch amortizes literal marshalling and executor
//! launch over the whole batch.
//!
//! [`StreamCoalescer`] is the streaming complement: a *single* recursive
//! stream cannot batch its own samples (each update consumes the
//! previous posterior), but **concurrent clients' streams are mutually
//! independent** — so each tick takes the next pending sample from every
//! active stream and fires them as ONE batched backend dispatch. On the
//! `XlaBatch` backend that wakes the `cn_update_batched` artifact, whose
//! runtime marshalling pads under-full tail batches (fewer active
//! streams than the baked batch size) up to the artifact's batch and
//! truncates on return.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;

use super::backend::{Backend, CnRequestData};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Longest a batch waits for stragglers before dispatching.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls from a channel and forms batches per the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    /// The batching policy in force.
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    /// Batcher over a request channel.
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block until at least one request is available, then keep
    /// collecting until the policy triggers. Returns `None` when the
    /// channel is closed and drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // block for the first element
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

/// One client's recursive compound-node stream as the coalescer sees
/// it: the running posterior plus queued per-sample
/// (observation, regressor) pairs.
pub struct CnStream {
    /// Current recursive state (the posterior after the last coalesced
    /// sample).
    pub state: GaussMessage,
    pending: VecDeque<(GaussMessage, CMatrix)>,
    /// Samples this stream has had coalesced so far.
    pub samples_done: u64,
}

impl CnStream {
    /// A stream starting from the given prior state.
    pub fn new(prior: GaussMessage) -> Self {
        CnStream { state: prior, pending: VecDeque::new(), samples_done: 0 }
    }

    /// Queue one sample: observation message `y` through regressor `a`.
    pub fn push(&mut self, y: GaussMessage, a: CMatrix) {
        self.pending.push_back((y, a));
    }

    /// Samples waiting to be coalesced.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Pop up to `max` samples off the front of the queue, in order.
    /// The serve tier's sticky path takes a chunk, ships it to the
    /// stream's pinned device as one chain request, and either
    /// [`commit`](Self::commit)s the advance or
    /// [`requeue_front`](Self::requeue_front)s the batch on a retryable
    /// device failure — so a sample leaves the stream's accounting only
    /// when its update has actually executed (zero-loss contract).
    pub fn take(&mut self, max: usize) -> Vec<(GaussMessage, CMatrix)> {
        let k = max.min(self.pending.len());
        self.pending.drain(..k).collect()
    }

    /// Put a taken-but-unexecuted batch back at the front of the queue,
    /// preserving sample order.
    pub fn requeue_front(&mut self, samples: Vec<(GaussMessage, CMatrix)>) {
        for s in samples.into_iter().rev() {
            self.pending.push_front(s);
        }
    }

    /// Record a successful advance of `advanced` samples ending in
    /// posterior `state`.
    pub fn commit(&mut self, state: GaussMessage, advanced: u64) {
        self.state = state;
        self.samples_done += advanced;
    }
}

/// Coalesces concurrent recursive CN streams into batched backend
/// dispatches (see the module docs for why cross-stream batching is
/// sound where within-stream batching is not).
pub struct StreamCoalescer;

impl StreamCoalescer {
    /// One coalescing round: take the next pending sample from every
    /// stream that has one, dispatch them as a single
    /// [`Backend::cn_update_batch`] call, and fold each result back into
    /// its stream's recursive state. Returns the number of streams
    /// advanced (0 = all drained). A stream whose update errors keeps
    /// its sample queued; the first such error is returned after every
    /// successful stream has still been advanced.
    pub fn tick(backend: &mut dyn Backend, streams: &mut [CnStream]) -> Result<usize> {
        let mut refs: Vec<&mut CnStream> = streams.iter_mut().collect();
        Self::tick_refs(backend, &mut refs)
    }

    /// [`tick`](Self::tick) over a borrowed selection of streams. The
    /// serve tier's registry keeps streams in a map keyed by session id,
    /// so a coalescing round operates on whatever subset its fairness
    /// rotor picked rather than a contiguous slice.
    pub fn tick_refs(backend: &mut dyn Backend, streams: &mut [&mut CnStream]) -> Result<usize> {
        let mut idx = Vec::with_capacity(streams.len());
        let mut reqs = Vec::with_capacity(streams.len());
        for (i, s) in streams.iter().enumerate() {
            if let Some((y, a)) = s.pending.front() {
                reqs.push(CnRequestData { x: s.state.clone(), y: y.clone(), a: a.clone() });
                idx.push(i);
            }
        }
        if reqs.is_empty() {
            return Ok(0);
        }
        let outs = backend.cn_update_batch(&reqs);
        let mut advanced = 0;
        let mut first_err = None;
        for (i, out) in idx.into_iter().zip(outs) {
            match out {
                Ok(post) => {
                    let s = &mut *streams[i];
                    s.state = post;
                    s.pending.pop_front();
                    s.samples_done += 1;
                    advanced += 1;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("coalesced update for stream {i}")));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(advanced),
        }
    }

    /// Tick until every stream's queue is drained.
    pub fn drain(backend: &mut dyn Backend, streams: &mut [CnStream]) -> Result<u64> {
        let mut total = 0u64;
        loop {
            let n = Self::tick(backend, streams)?;
            if n == 0 {
                return Ok(total);
            }
            total += n as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn flushes_on_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) },
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn coalescer_matches_sequential_updates() {
        use super::super::backend::GoldenBackend;
        use crate::gmp::matrix::c64;
        use crate::gmp::nodes;
        use crate::testutil::Rng;

        let mut rng = Rng::new(11);
        let msg = |rng: &mut Rng| {
            GaussMessage::new(
                (0..4).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
                CMatrix::random_psd(rng, 4, 1.0).scale(0.15),
            )
        };
        // three concurrent streams of different lengths: later ticks run
        // under-full ("tail") batches as the short streams drain
        let lens = [4usize, 2, 3];
        let mut streams: Vec<CnStream> = Vec::new();
        let mut priors: Vec<GaussMessage> = Vec::new();
        let mut samples: Vec<Vec<(GaussMessage, CMatrix)>> = Vec::new();
        for &len in &lens {
            let prior = msg(&mut rng);
            let mut s = CnStream::new(prior.clone());
            let mut data = Vec::new();
            for _ in 0..len {
                let y = msg(&mut rng);
                let a = CMatrix::random(&mut rng, 4, 4).scale(0.3);
                s.push(y.clone(), a.clone());
                data.push((y, a));
            }
            streams.push(s);
            priors.push(prior);
            samples.push(data);
        }
        let mut backend = GoldenBackend;
        let total = StreamCoalescer::drain(&mut backend, &mut streams).unwrap();
        assert_eq!(total, 9);
        // each stream's final state == folding its own samples alone:
        // cross-stream batching never mixes the recursions
        for (i, s) in streams.iter().enumerate() {
            assert_eq!(s.samples_done as usize, lens[i]);
            assert_eq!(s.pending(), 0);
            let mut want = priors[i].clone();
            for (y, a) in &samples[i] {
                want = nodes::compound_observation(&want, y, a, false).unwrap();
            }
            assert!(s.state.dist(&want) < 1e-12, "stream {i}: {}", s.state.dist(&want));
        }
    }

    #[test]
    fn take_requeue_commit_preserve_order() {
        use crate::gmp::matrix::c64;
        use crate::testutil::Rng;

        let mut rng = Rng::new(21);
        let msg = |rng: &mut Rng| {
            GaussMessage::new(
                (0..2).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
                CMatrix::random_psd(rng, 2, 0.5),
            )
        };
        let mut s = CnStream::new(msg(&mut rng));
        let samples: Vec<(GaussMessage, CMatrix)> =
            (0..5).map(|_| (msg(&mut rng), CMatrix::random(&mut rng, 2, 2))).collect();
        for (y, a) in &samples {
            s.push(y.clone(), a.clone());
        }
        let batch = s.take(3);
        assert_eq!((batch.len(), s.pending()), (3, 2));
        assert!(batch[0].0.dist(&samples[0].0) == 0.0);
        // a failed dispatch puts the batch back exactly where it was
        s.requeue_front(batch);
        assert_eq!(s.pending(), 5);
        let again = s.take(5);
        for (got, want) in again.iter().zip(&samples) {
            assert!(got.0.dist(&want.0) == 0.0 && got.1.dist(&want.1) == 0.0);
        }
        let post = msg(&mut rng);
        s.commit(post.clone(), 5);
        assert_eq!(s.samples_done, 5);
        assert!(s.state.dist(&post) == 0.0);
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![7, 8]);
        assert!(b.next_batch().is_none());
    }
}
