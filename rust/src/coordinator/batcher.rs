//! Dynamic batching (the serving-side half of the offload path).
//!
//! Classic max-batch / max-wait policy: a batch is dispatched as soon as
//! it reaches `max_batch` requests, or when the oldest queued request has
//! waited `max_wait`, whichever comes first. With the PJRT batched
//! artifact, one dispatch amortizes literal marshalling and executor
//! launch over the whole batch.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls from a channel and forms batches per the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block until at least one request is available, then keep
    /// collecting until the policy triggers. Returns `None` when the
    /// channel is closed and drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // block for the first element
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn flushes_on_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) },
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![7, 8]);
        assert!(b.next_batch().is_none());
    }
}
