//! S9 — The coordinator: the "external processor" of Fig. 5 as a service.
//!
//! §III: "the FGP can be easily attached to an existing system as an
//! accelerator or a co-processor" — this module is that existing system.
//! It owns the request path end to end:
//!
//! * [`backend`] — pluggable message-update engines behind the unified
//!   [`crate::engine::Session`] surface: the cycle-accurate FGP
//!   simulator, the f64 golden rules, and the PJRT/XLA artifacts (single
//!   and batched). Requests are either raw compound-node updates
//!   (batchable) or general [`backend::WorkloadRequest`]s —
//!   compiled-program executions with streamed sections;
//! * [`batcher`] — dynamic batching with a max-batch / max-wait policy
//!   (amortizes PJRT dispatch across requests, the classic serving
//!   trade-off), plus [`StreamCoalescer`]: concurrent clients'
//!   *recursive* streams — unbatchable individually — coalesced
//!   cross-stream into `cn_update_batched` dispatches with padded tail
//!   batches;
//! * [`farm`] — the multi-device scale-out: routed one-shot workloads,
//!   and **sticky stream sessions** ([`FgpFarm::open_stream`]) where a
//!   recursive app's chunks always land on the same device so its
//!   compiled chunk program stays cached and PM-resident while the
//!   per-stream state persists across samples. Device membership is
//!   dynamic ([`FgpFarm::kill_device`] / [`FgpFarm::revive_device`]),
//!   failures surface as typed retryable [`FarmError`]s, and streams
//!   checkpoint/fail-over bitwise-identically — the substrate the
//!   network serving tier ([`crate::serve`]) is built on;
//! * [`server`] — worker threads pulling from an mpsc queue, a cloneable
//!   client handle, graceful shutdown;
//! * [`device`] — the raw Fig. 5 command protocol (`load_program`,
//!   `start_program`, status replies) behind a thread, for host-style
//!   integration;
//! * [`metrics`] — latency histograms and throughput counters.
//!
//! No tokio in the vendored crate set: the runtime is std threads +
//! channels, which for a CPU-bound accelerator front-end is exactly as
//! effective and considerably simpler.

pub mod backend;
pub mod batcher;
pub mod device;
pub mod farm;
pub mod metrics;
pub mod server;

pub use backend::{Backend, BackendKind, CnRequestData, WorkloadRequest};
pub use batcher::{BatchPolicy, Batcher, CnStream, StreamCoalescer};
pub use device::{FgpDevice, ProtocolError};
pub use farm::{recv_exec, FarmCnBackend, FarmError, FarmStream, FgpFarm, RoutePolicy};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use server::{CnClient, CnServer, ServerClosed, ServerConfig};
