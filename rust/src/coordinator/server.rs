//! The coordinator server: queue → batcher → backend → reply.
//!
//! A [`CnServer`] owns a worker thread driving one [`Backend`]; clients
//! hold a cheap cloneable [`CnClient`] and submit either compound-node
//! updates (batched per the policy) or general **workload requests**
//! (compiled-program executions with streamed sections,
//! [`WorkloadRequest`]) — synchronously ([`CnClient::update`],
//! [`CnClient::run_workload`]) or asynchronously ([`CnClient::submit`],
//! [`CnClient::submit_workload`] + the returned receiver). Shutdown is
//! by dropping the server (or all clients); a client talking to a dead
//! server gets a typed [`ServerClosed`] error on the reply channel, not
//! a bare disconnect.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::engine::Execution;
use crate::gmp::message::GaussMessage;

use super::backend::{Backend, CnRequestData, WorkloadRequest};
use super::batcher::BatchPolicy;
use super::metrics::Metrics;

/// Typed error surfaced to clients whose server is gone (either it never
/// finished booting, it was shut down, or its thread died).
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
#[error("coordinator server closed")]
pub struct ServerClosed;

/// Server configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerConfig {
    /// Dynamic-batching policy for the worker.
    pub batch: BatchPolicy,
}

struct Envelope {
    data: CnRequestData,
    enqueued: Instant,
    resp: Sender<Result<GaussMessage>>,
}

struct WorkloadEnvelope {
    data: WorkloadRequest,
    enqueued: Instant,
    resp: Sender<Result<Execution>>,
}

enum ServerMsg {
    Cn(Envelope),
    Workload(WorkloadEnvelope),
    /// Explicit stop marker so shutdown does not depend on every client
    /// clone being dropped first.
    Stop,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct CnClient {
    tx: Sender<ServerMsg>,
    metrics: Arc<Metrics>,
}

impl CnClient {
    /// Fire a CN request; the reply arrives on the returned receiver. If
    /// the server is gone the receiver immediately yields
    /// `Err(ServerClosed)`.
    pub fn submit(&self, data: CnRequestData) -> Receiver<Result<GaussMessage>> {
        let (rtx, rrx) = mpsc::channel();
        let env = Envelope { data, enqueued: Instant::now(), resp: rtx.clone() };
        if self.tx.send(ServerMsg::Cn(env)).is_err() {
            let _ = rtx.send(Err(ServerClosed.into()));
        }
        rrx
    }

    /// Fire a workload request; same reply-channel contract as
    /// [`CnClient::submit`].
    pub fn submit_workload(&self, data: WorkloadRequest) -> Receiver<Result<Execution>> {
        let (rtx, rrx) = mpsc::channel();
        let env = WorkloadEnvelope { data, enqueued: Instant::now(), resp: rtx.clone() };
        if self.tx.send(ServerMsg::Workload(env)).is_err() {
            let _ = rtx.send(Err(ServerClosed.into()));
        }
        rrx
    }

    /// Synchronous CN update.
    pub fn update(&self, data: CnRequestData) -> Result<GaussMessage> {
        self.submit(data)
            .recv()
            .map_err(|_| anyhow::Error::new(ServerClosed))?
    }

    /// Synchronous workload execution.
    pub fn run_workload(&self, data: WorkloadRequest) -> Result<Execution> {
        self.submit_workload(data)
            .recv()
            .map_err(|_| anyhow::Error::new(ServerClosed))?
    }

    /// Shared server metrics (latency, batch sizes).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// The server: one worker thread around a backend.
///
/// The backend is built *inside* the worker thread (PJRT clients are
/// thread-affine), so `start` takes a factory. Construction failure is
/// reported synchronously.
pub struct CnServer {
    handle: Option<JoinHandle<()>>,
    client: CnClient,
}

impl CnServer {
    /// Start a server; `factory` builds the backend on the worker thread.
    pub fn start<F>(factory: F, config: ServerConfig) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let metrics = Arc::new(Metrics::new());
        let worker_metrics = Arc::clone(&metrics);
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("fgp-cn-server".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = boot_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                // workload requests execute as they arrive; CN requests
                // batch per the policy (plus the explicit Stop marker)
                let run_workload =
                    |backend: &mut dyn Backend, env: WorkloadEnvelope, m: &Metrics| {
                        // queue wait ends at dequeue, before execution
                        // (same semantics as the CN batch path)
                        m.record_batch(1);
                        m.queue_wait.record(env.enqueued.elapsed());
                        let result = backend.run_workload(&env.data);
                        match &result {
                            Ok(_) => {
                                m.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                            Err(_) => {
                                m.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        m.latency.record(env.enqueued.elapsed());
                        let _ = env.resp.send(result);
                    };
                let mut stopping = false;
                while !stopping {
                    let first = match rx.recv() {
                        Ok(ServerMsg::Cn(env)) => env,
                        Ok(ServerMsg::Workload(env)) => {
                            run_workload(&mut backend, env, &worker_metrics);
                            continue;
                        }
                        Ok(ServerMsg::Stop) | Err(_) => break,
                    };
                    let mut batch = vec![first];
                    let deadline = Instant::now() + config.batch.max_wait;
                    while batch.len() < config.batch.max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(ServerMsg::Cn(env)) => batch.push(env),
                            Ok(ServerMsg::Workload(env)) => {
                                run_workload(&mut backend, env, &worker_metrics);
                            }
                            Ok(ServerMsg::Stop) => {
                                stopping = true;
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                    let now = Instant::now();
                    worker_metrics.record_batch(batch.len());
                    for env in &batch {
                        worker_metrics.queue_wait.record(now - env.enqueued);
                    }
                    let datas: Vec<CnRequestData> =
                        batch.iter().map(|e| e.data.clone()).collect();
                    let results = backend.cn_update_batch(&datas);
                    for (env, result) in batch.into_iter().zip(results) {
                        match &result {
                            Ok(_) => {
                                worker_metrics
                                    .completed
                                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                            Err(_) => {
                                worker_metrics
                                    .failed
                                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        worker_metrics.latency.record(env.enqueued.elapsed());
                        let _ = env.resp.send(result);
                    }
                }
                // drain: requests still queued (behind the Stop marker,
                // or raced in while exiting) get the typed error instead
                // of a dropped reply channel
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        ServerMsg::Cn(env) => {
                            worker_metrics
                                .failed
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            let _ = env.resp.send(Err(ServerClosed.into()));
                        }
                        ServerMsg::Workload(env) => {
                            worker_metrics
                                .failed
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            let _ = env.resp.send(Err(ServerClosed.into()));
                        }
                        ServerMsg::Stop => {}
                    }
                }
            })
            .expect("spawn server thread");
        boot_rx
            .recv()
            .map_err(|_| anyhow::Error::new(ServerClosed))??;
        Ok(CnServer { handle: Some(handle), client: CnClient { tx, metrics } })
    }

    /// A cloneable client handle to this server.
    pub fn client(&self) -> CnClient {
        self.client.clone()
    }

    /// Graceful shutdown: close the queue and join the worker (the Drop
    /// impl does the same; this form just makes intent explicit).
    pub fn shutdown(self) {}
}

impl Drop for CnServer {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.client.tx.send(ServerMsg::Stop);
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::GoldenBackend;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::Rng;

    fn request(rng: &mut Rng, n: usize) -> CnRequestData {
        CnRequestData {
            x: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
                CMatrix::random_psd(rng, n, 0.3),
            ),
            y: GaussMessage::new(
                (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
                CMatrix::random_psd(rng, n, 0.3),
            ),
            a: CMatrix::random(rng, n, n),
        }
    }

    #[test]
    fn serves_sync_requests() {
        let server =
            CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default())
                .unwrap();
        let client = server.client();
        let mut rng = Rng::new(1);
        for _ in 0..8 {
            let req = request(&mut rng, 4);
            let out = client.update(req.clone()).unwrap();
            let want =
                crate::gmp::nodes::compound_observation(&req.x, &req.y, &req.a, false).unwrap();
            assert!(out.dist(&want) < 1e-9);
        }
        assert_eq!(
            client.metrics().completed.load(std::sync::atomic::Ordering::Relaxed),
            8
        );
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_submitters() {
        let server =
            CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default())
                .unwrap();
        let mut joins = Vec::new();
        for t in 0..4 {
            let client = server.client();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let rxs: Vec<_> =
                    (0..16).map(|_| client.submit(request(&mut rng, 4))).collect();
                for rx in rxs {
                    rx.recv().unwrap().unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = server.client();
        assert_eq!(m.metrics().completed.load(std::sync::atomic::Ordering::Relaxed), 64);
        assert!(m.metrics().mean_batch_size() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn serves_workload_requests() {
        use crate::apps::rls::RlsProblem;
        use crate::coordinator::backend::WorkloadRequest;
        use crate::engine::Workload;

        let server =
            CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default())
                .unwrap();
        let client = server.client();
        let p = RlsProblem::synthetic(4, 12, 0.02, 5);
        let wr = WorkloadRequest::from_workload(&p).unwrap();
        let exec = client.run_workload(wr).unwrap();
        let outcome = p.outcome(&exec).unwrap();
        assert!(outcome.rel_mse < 0.1, "rel MSE {}", outcome.rel_mse);
        server.shutdown();
    }

    #[test]
    fn closed_server_yields_typed_error() {
        let server =
            CnServer::start(|| Ok(Box::new(GoldenBackend) as _), ServerConfig::default())
                .unwrap();
        let client = server.client(); // clone outlives the server
        server.shutdown();
        let mut rng = Rng::new(1);
        // the receiver carries a typed ServerClosed, not a bare disconnect
        let rx = client.submit(request(&mut rng, 4));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.is::<ServerClosed>(), "unexpected error: {err:#}");
        let err = client.update(request(&mut rng, 4)).unwrap_err();
        assert!(err.is::<ServerClosed>(), "unexpected error: {err:#}");
    }

    #[test]
    fn boot_failure_reported_synchronously() {
        let result = CnServer::start(
            || Err(anyhow::anyhow!("backend exploded")),
            ServerConfig::default(),
        );
        assert!(result.is_err());
        assert!(format!("{:#}", result.err().unwrap()).contains("exploded"));
    }
}
