//! Serving metrics: log-bucketed latency histograms + throughput counters.
//!
//! Self-contained (no external metrics crates in the vendored set).
//! Buckets are powers of two in nanoseconds, which gives ~1.4 significant
//! digits over twelve decades — plenty for latency reporting — at a
//! fixed 64-counter footprint, lock-free on the hot path via atomics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log2-bucketed histogram of durations.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    /// Midpoint of the bucket containing quantile `q` (0..1].
    ///
    /// Bucket `i` covers `[2^i, 2^(i+1) - 1]` ns. The midpoint halves
    /// the worst-case bias of the old upper-bound convention (which
    /// reported ~2µs for a bucket full of 1µs samples — a 2× error at
    /// the low end) and stays monotone in `q`, so snapshot quantile
    /// ordering (p50 ≤ p95 ≤ p99) is preserved.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let lower = 1u64 << i;
                let upper = if i == 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
                return Duration::from_nanos(lower + (upper - lower) / 2);
            }
        }
        Duration::from_nanos(u64::MAX)
    }

    /// Fold `other`'s samples into this histogram — bucket-wise atomic
    /// adds, so cross-device aggregation (each farm device keeps local
    /// histograms; the obs registry merges them at snapshot time) needs
    /// no locks and loses no samples.
    pub fn merge(&self, other: &Histogram) {
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = o.load(Ordering::Relaxed);
            if v != 0 {
                b.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Clear all buckets and counters.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
    }
}

/// A point-in-time copy of a [`Metrics`] bundle's latency distribution
/// and completion counters: the SLO row the serve tier ships over the
/// wire in a `STATS` reply and the bench layer writes to
/// `BENCH_serving.json`. Quantiles are log2-bucket midpoints (see
/// [`Histogram::quantile`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Requests completed.
    pub completed: u64,
    /// Requests failed.
    pub failed: u64,
    /// Mean end-to-end latency in nanoseconds.
    pub mean_ns: u64,
    /// p50 latency in nanoseconds.
    pub p50_ns: u64,
    /// p95 latency in nanoseconds.
    pub p95_ns: u64,
    /// p99 latency in nanoseconds.
    pub p99_ns: u64,
}

/// Serving metrics bundle shared between workers and observers.
#[derive(Debug, Default)]
pub struct Metrics {
    /// End-to-end request latency (enqueue -> reply).
    pub latency: Histogram,
    /// Time a request waited in the batcher.
    pub queue_wait: Histogram,
    /// Requests completed.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Batches dispatched.
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one dispatched batch of the given size.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Point-in-time latency/completion snapshot (see [`MetricsSnapshot`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            mean_ns: self.latency.mean().as_nanos().min(u128::from(u64::MAX)) as u64,
            p50_ns: self.latency.quantile(0.5).as_nanos().min(u128::from(u64::MAX)) as u64,
            p95_ns: self.latency.quantile(0.95).as_nanos().min(u128::from(u64::MAX)) as u64,
            p99_ns: self.latency.quantile(0.99).as_nanos().min(u128::from(u64::MAX)) as u64,
        }
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests: {} ok, {} failed | batches: {} (mean size {:.1}) | \
             latency mean {:?} p50 {:?} p99 {:?} | queue wait mean {:?}",
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.queue_wait.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_ranks() {
        let h = Histogram::new();
        for us in [1u64, 10, 100, 1000] {
            for _ in 0..10 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 40);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        let mean = h.mean();
        assert!(mean > Duration::from_micros(100) && mean < Duration::from_micros(1000));
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(Duration::from_millis(5));
        h.reset();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn snapshot_orders_quantiles() {
        let m = Metrics::new();
        for us in [10u64, 100, 1000, 10_000] {
            for _ in 0..5 {
                m.latency.record(Duration::from_micros(us));
            }
        }
        m.completed.store(20, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.completed, 20);
        assert!(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns, "{s:?}");
        assert!(s.mean_ns > 0);
    }

    #[test]
    fn quantile_returns_bucket_midpoints() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(1)); // 1000 ns → bucket 9 = [512, 1023]
        }
        assert_eq!(h.quantile(0.5), Duration::from_nanos(512 + (1023 - 512) / 2));
        assert_eq!(h.quantile(0.99), h.quantile(0.5), "single-bucket data has flat quantiles");
        // the smallest bucket [1, 1] is exact
        let h1 = Histogram::new();
        h1.record(Duration::from_nanos(1));
        assert_eq!(h1.quantile(0.5), Duration::from_nanos(1));
    }

    #[test]
    fn merge_is_bucketwise_sum() {
        let a = Histogram::new();
        let b = Histogram::new();
        for _ in 0..10 {
            a.record(Duration::from_micros(1));
        }
        for _ in 0..30 {
            b.record(Duration::from_micros(100));
        }
        a.merge(&b);
        assert_eq!(a.count(), 40);
        assert_eq!(a.mean(), Duration::from_nanos((10 * 1_000 + 30 * 100_000) / 40));
        assert!(a.quantile(0.5) <= a.quantile(0.95), "merged quantiles stay ordered");
        assert!(a.quantile(0.9) > a.quantile(0.1), "both sources visible after merge");
    }

    /// One sample: every quantile lands in that sample's bucket — the
    /// `ceil(total·q)` target must clamp to rank 1, never rank 0.
    #[test]
    fn single_sample_pins_every_quantile() {
        let h = Histogram::new();
        h.record(Duration::from_micros(1)); // bucket 9 = [512, 1023]
        let mid = Duration::from_nanos(512 + (1023 - 512) / 2);
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), mid, "q={q}");
        }
        assert_eq!(h.mean(), Duration::from_micros(1));
        assert_eq!(h.count(), 1);
    }

    /// Zero-duration samples are clamped into bucket 0 (`ns.max(1)`),
    /// not dropped and not a shift overflow.
    #[test]
    fn zero_duration_sample_lands_in_bucket_zero() {
        let h = Histogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), Duration::from_nanos(1));
        assert_eq!(h.mean(), Duration::ZERO, "sum is untouched by the bucket clamp");
    }

    /// Merging mismatched occupancies: one side heavily loaded, the
    /// other nearly empty (and disjoint buckets). Count, sum and the
    /// rank walk must all see the union.
    #[test]
    fn merge_with_mismatched_occupancy_buckets() {
        let heavy = Histogram::new();
        for _ in 0..99 {
            heavy.record(Duration::from_nanos(100)); // bucket 6
        }
        let sparse = Histogram::new();
        sparse.record(Duration::from_micros(100)); // bucket 16 — disjoint
        heavy.merge(&sparse);
        assert_eq!(heavy.count(), 100);
        // 99 of 100 samples below: p50/p95 stay in the heavy bucket...
        assert_eq!(heavy.quantile(0.95), Duration::from_nanos(64 + (127 - 64) / 2));
        // ...and p100 reaches the sparse one
        assert_eq!(heavy.quantile(1.0), Duration::from_nanos(65_536 + (131_071 - 65_536) / 2));
        // merging an empty histogram is the identity
        let before = (heavy.count(), heavy.mean(), heavy.quantile(0.5));
        heavy.merge(&Histogram::new());
        assert_eq!((heavy.count(), heavy.mean(), heavy.quantile(0.5)), before);
        // and merging *into* an empty histogram copies the source
        let empty = Histogram::new();
        empty.merge(&heavy);
        assert_eq!(empty.count(), heavy.count());
        assert_eq!(empty.quantile(0.5), heavy.quantile(0.5));
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        assert!(m.report().contains("batches: 2"));
    }
}
