//! # fgp-repro — A Signal Processor for Gaussian Message Passing
//!
//! Production-quality reproduction of Kröll et al., *"A Signal Processor
//! for Gaussian Message Passing"* (2014): the **FGP**, an application-
//! specific instruction processor whose datapath is a configurable
//! systolic array executing Gaussian message-passing (GMP) updates on
//! factor graphs.
//!
//! The original is a UMC180 ASIC; this crate substitutes a **cycle-
//! accurate software model** of the microarchitecture plus an analytic
//! model of the paper's TI C66x DSP baseline (the paper itself estimated
//! the DSP cycles analytically). See `DESIGN.md` for the substitution
//! table and the per-experiment index.
//!
//! ## Layer map (three-layer rust + JAX + Pallas architecture)
//!
//! * **L3 (this crate)** — the paper's contribution: [`fgp`] cycle-accurate
//!   simulator, [`isa`] + [`compiler`], [`engine`] (the unified
//!   Workload/Engine/Session execution surface, including the
//!   **streaming steady-state path** `Session::run_stream` — compile
//!   once, stream samples through the resident program, the §VI
//!   throughput shape), [`coordinator`] (the Fig. 5 "external
//!   processor" command protocol, request queue, batcher, device farm
//!   with sticky stream sessions and cross-stream coalescing), [`serve`]
//!   (the network serving tier: a std-only TCP front door with
//!   per-tenant admission control, explicit backpressure, bitwise
//!   stream checkpoint/failover across farm members, and wire-exported
//!   SLO metrics), [`obs`] (end-to-end telemetry: trace contexts carried
//!   through the wire codec and across every layer, a lock-free span
//!   ring, a unified metrics registry, and Chrome-trace/flame
//!   exporters — off by default, bitwise-inert when disabled), [`gbp`]
//!   (loopy Gaussian belief propagation over cyclic graphs, every inner
//!   update dispatched through the engine surface), [`nonlinear`]
//!   (pluggable EKF/sigma-point linearizers and iterated
//!   relinearization turning nonlinear factors into cache-hitting
//!   compound-observation sweeps), [`em`] (EM parameter estimation —
//!   unknown noise variances and coefficients estimated from the
//!   posterior marginals any session run produces, batch or online),
//!   [`dsp`] baseline and [`model`] area/technology models.
//! * **L2/L1 (python/, build-time only)** — the GMP compute graph in JAX
//!   with fused Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from [`runtime`] via the PJRT C API. Python never runs on
//!   the request path.
//!
//! ## Quick start
//!
//! Every application is a [`engine::Workload`] (a factor-graph model plus
//! host-side data) and every backend is an [`engine::Engine`] behind one
//! [`engine::Session`] — the same `Session::run` call drives the f64
//! golden rules, the cycle-accurate simulator, and the PJRT/XLA runtime.
//!
//! ```no_run
//! use fgp_repro::apps::rls::RlsProblem;
//! use fgp_repro::engine::Session;
//! use fgp_repro::fgp::FgpConfig;
//!
//! // The paper's Fig. 6 channel-estimation workload, compiled to FGP
//! // assembler and run on the cycle-accurate simulator.
//! let problem = RlsProblem::synthetic(4, 16, 0.01, 42);
//! let mut session = Session::fgp_sim(FgpConfig::default());
//! let report = session.run(&problem).unwrap();
//! println!("rel MSE = {}", report.quality);
//! println!("cycles/section = {}", report.cycles_per_section);
//!
//! // Same workload, golden reference engine — same call.
//! let reference = Session::golden().run(&problem).unwrap();
//! assert!(report.quality < reference.quality + 0.2);
//!
//! // Steady-state serving (§VI): compile once, stream the samples
//! // through the resident program — Table II's throughput shape.
//! let stream = session.run_stream(&problem).unwrap();
//! assert_eq!(stream.samples, 16);
//! ```
//!
//! Measured streaming-vs-per-call throughput per engine is published to
//! `BENCH_throughput.json` by `cargo bench --bench table2_throughput`
//! (E14 in `DESIGN.md`).

#![warn(missing_docs)]

pub mod apps;
pub mod benchutil;
pub mod compiler;
pub mod coordinator;
pub mod dsp;
pub mod em;
pub mod engine;
pub mod fixed;
pub mod fgp;
pub mod gbp;
pub mod gmp;
pub mod isa;
pub mod kernels;
pub mod model;
pub mod nonlinear;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod testutil;

/// Paper constants used across benches and reports (Table II, §V).
pub mod paper {
    /// State-matrix size the silicon was synthesized for (4x4 complex).
    pub const N: usize = 4;
    /// FGP maximum clock frequency in MHz at UMC180 (Table II).
    pub const FGP_FREQ_MHZ: f64 = 130.0;
    /// FGP technology node in nm.
    pub const FGP_NODE_NM: f64 = 180.0;
    /// Cycles the paper reports for one compound-node message update.
    pub const FGP_CN_CYCLES: u64 = 260;
    /// TI C66x clock frequency in MHz (40 nm, ref [10]).
    pub const DSP_FREQ_MHZ: f64 = 1250.0;
    /// TI C66x technology node in nm.
    pub const DSP_NODE_NM: f64 = 40.0;
    /// Cycles the paper estimates for the C66x compound-node update.
    pub const DSP_CN_CYCLES: u64 = 1076;
    /// Cycles for a complex 4x4 matrix inversion on the C66x (ref [11]).
    pub const DSP_INV4_CYCLES: u64 = 768;
    /// Total FGP area in mm^2 (UMC180 synthesis).
    pub const FGP_AREA_MM2: f64 = 3.11;
    /// Area fractions: memories / systolic array / datapath+control.
    pub const FGP_AREA_SPLIT: [f64; 3] = [0.30, 0.60, 0.10];
    /// Message-memory capacity in kbit (both processors, Table II).
    pub const MEMORY_KBIT: usize = 64;
}
