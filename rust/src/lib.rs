//! # fgp-repro — A Signal Processor for Gaussian Message Passing
//!
//! Production-quality reproduction of Kröll et al., *"A Signal Processor
//! for Gaussian Message Passing"* (2014): the **FGP**, an application-
//! specific instruction processor whose datapath is a configurable
//! systolic array executing Gaussian message-passing (GMP) updates on
//! factor graphs.
//!
//! The original is a UMC180 ASIC; this crate substitutes a **cycle-
//! accurate software model** of the microarchitecture plus an analytic
//! model of the paper's TI C66x DSP baseline (the paper itself estimated
//! the DSP cycles analytically). See `DESIGN.md` for the substitution
//! table and the per-experiment index.
//!
//! ## Layer map (three-layer rust + JAX + Pallas architecture)
//!
//! * **L3 (this crate)** — the paper's contribution: [`fgp`] cycle-accurate
//!   simulator, [`isa`] + [`compiler`], [`coordinator`] (the Fig. 5
//!   "external processor" command protocol, request queue, batcher),
//!   [`dsp`] baseline and [`model`] area/technology models.
//! * **L2/L1 (python/, build-time only)** — the GMP compute graph in JAX
//!   with fused Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from [`runtime`] via the PJRT C API. Python never runs on
//!   the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use fgp_repro::gmp::matrix::CMatrix;
//! use fgp_repro::apps::rls::RlsProblem;
//! use fgp_repro::fgp::processor::Fgp;
//!
//! // Build the paper's Fig. 6 channel-estimation factor graph, compile it
//! // to FGP assembler, and run it on the cycle-accurate simulator.
//! let problem = RlsProblem::synthetic(4, 16, 0.01, 42);
//! let outcome = problem.run_on_fgp().unwrap();
//! println!("cycles/section = {}", outcome.cycles_per_section);
//! ```

pub mod apps;
pub mod benchutil;
pub mod compiler;
pub mod coordinator;
pub mod dsp;
pub mod fixed;
pub mod fgp;
pub mod gmp;
pub mod isa;
pub mod model;
pub mod runtime;
pub mod testutil;

/// Paper constants used across benches and reports (Table II, §V).
pub mod paper {
    /// State-matrix size the silicon was synthesized for (4x4 complex).
    pub const N: usize = 4;
    /// FGP maximum clock frequency in MHz at UMC180 (Table II).
    pub const FGP_FREQ_MHZ: f64 = 130.0;
    /// FGP technology node in nm.
    pub const FGP_NODE_NM: f64 = 180.0;
    /// Cycles the paper reports for one compound-node message update.
    pub const FGP_CN_CYCLES: u64 = 260;
    /// TI C66x clock frequency in MHz (40 nm, ref [10]).
    pub const DSP_FREQ_MHZ: f64 = 1250.0;
    /// TI C66x technology node in nm.
    pub const DSP_NODE_NM: f64 = 40.0;
    /// Cycles the paper estimates for the C66x compound-node update.
    pub const DSP_CN_CYCLES: u64 = 1076;
    /// Cycles for a complex 4x4 matrix inversion on the C66x (ref [11]).
    pub const DSP_INV4_CYCLES: u64 = 768;
    /// Total FGP area in mm^2 (UMC180 synthesis).
    pub const FGP_AREA_MM2: f64 = 3.11;
    /// Area fractions: memories / systolic array / datapath+control.
    pub const FGP_AREA_SPLIT: [f64; 3] = [0.30, 0.60, 0.10];
    /// Message-memory capacity in kbit (both processors, Table II).
    pub const MEMORY_KBIT: usize = 64;
}
