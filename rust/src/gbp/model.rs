//! Cyclic Gaussian factor-graph models and their dense reference.
//!
//! A [`GbpModel`] is a *variable/factor* view of an estimation problem —
//! the representation loopy belief propagation iterates over — as
//! opposed to [`crate::gmp::FactorGraph`], which is a *scheduled
//! dataflow* view (one node update per step, no cycles). The solver
//! lowers every per-edge GBP update back onto a small scheduled
//! `FactorGraph` so the inner kernel still runs on any
//! [`crate::engine::Engine`]; this module only owns the model and its
//! exact dense information-form solution (the conformance reference).

use anyhow::{bail, Context, Result};

use crate::gmp::matrix::{c64, CMatrix, CVector};
use crate::gmp::message::GaussMessage;
use crate::nonlinear::{Linearizer, NonlinearFactor, PairwiseNonlinear};

/// Identifies a variable in a [`GbpModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarId(pub usize);

/// Identifies a factor in a [`GbpModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FactorId(pub usize);

/// A variable: an `n`-dimensional complex Gaussian unknown.
#[derive(Clone, Debug)]
pub struct Variable {
    /// Proper prior, if any. Variables without a prior must have at
    /// least two pairwise factors (so every cavity stays proper).
    pub prior: Option<GaussMessage>,
    /// Human-readable name (diagnostics).
    pub label: String,
}

/// A factor connecting one or two variables.
#[derive(Clone, Debug)]
pub enum Factor {
    /// Linear observation of one variable: `y = C x + v`, `v ~ N(0, R)`
    /// with `R` the covariance of `obs` and `y` its mean. Rank-deficient
    /// `C` is fine (rows of `C` that are zero observe pure noise and add
    /// no information) — this is exactly the conditioning the compound
    /// observation node computes, so unary factors ride the CN kernel.
    Unary { var: VarId, c: CMatrix, obs: GaussMessage },
    /// Linear-Gaussian link `x_to = A x_from + w`, `w ~ N(b, Q)` with
    /// `b`/`Q` the mean/covariance of `noise` (odometry displacements
    /// ride as the noise mean). `A` must be invertible so the reverse
    /// message exists; `a_inv` is cached at construction.
    Pairwise {
        from: VarId,
        to: VarId,
        a: CMatrix,
        a_inv: CMatrix,
        noise: GaussMessage,
    },
    /// Nonlinear observation `z = h(x) + v` of one variable,
    /// relinearized at the variable's **current belief** every solver
    /// round (Ortiz et al. 2021) by the solver's pluggable
    /// [`Linearizer`]; its linear stand-in rides the same CN kernel as
    /// [`Factor::Unary`].
    NonlinearUnary { var: VarId, f: NonlinearFactor },
    /// Nonlinear relative measurement `z = h(x_from, x_to) + v` (e.g. an
    /// inter-pose range), relinearized at both endpoints' current
    /// beliefs every round. Unlike [`Factor::Pairwise`] the linearized
    /// model may be rank-deficient, so its messages are grafted onto a
    /// vague base instead of requiring an invertible transform.
    NonlinearPairwise { from: VarId, to: VarId, f: PairwiseNonlinear },
}

/// A cyclic-capable Gaussian model: variables plus unary/pairwise
/// factors. Cycles are first-class — this is what
/// [`crate::gmp::Schedule`] cannot represent.
#[derive(Clone, Debug, Default)]
pub struct GbpModel {
    n: usize,
    vars: Vec<Variable>,
    factors: Vec<Factor>,
    /// Per-variable pairwise adjacency in factor order, maintained on
    /// insert: per-edge requests on the solver hot path must not
    /// rescan the whole factor list.
    pairwise_idx: Vec<Vec<FactorId>>,
    /// Per-variable unary factors in factor order.
    unary_idx: Vec<Vec<FactorId>>,
}

impl GbpModel {
    /// An empty model over `n`-dimensional variables.
    pub fn new(n: usize) -> Self {
        GbpModel {
            n,
            vars: Vec::new(),
            factors: Vec::new(),
            pairwise_idx: Vec::new(),
            unary_idx: Vec::new(),
        }
    }

    /// Variable dimension (must match the device size to run on the FGP).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// Number of factors.
    pub fn num_factors(&self) -> usize {
        self.factors.len()
    }

    /// The variable behind an id.
    pub fn variable(&self, v: VarId) -> &Variable {
        &self.vars[v.0]
    }

    /// The factor behind an id.
    pub fn factor(&self, f: FactorId) -> &Factor {
        &self.factors[f.0]
    }

    /// All factors in insertion order.
    pub fn factors(&self) -> &[Factor] {
        &self.factors
    }

    /// Add a variable with an optional proper prior.
    pub fn add_variable(
        &mut self,
        prior: Option<GaussMessage>,
        label: impl Into<String>,
    ) -> Result<VarId> {
        if let Some(p) = &prior {
            if p.dim() != self.n {
                bail!("prior has dim {} but the model is n={}", p.dim(), self.n);
            }
        }
        self.vars.push(Variable { prior, label: label.into() });
        self.pairwise_idx.push(Vec::new());
        self.unary_idx.push(Vec::new());
        Ok(VarId(self.vars.len() - 1))
    }

    /// Add a unary observation factor `y = C x + v`.
    pub fn add_unary(&mut self, var: VarId, c: CMatrix, obs: GaussMessage) -> Result<FactorId> {
        if var.0 >= self.vars.len() {
            bail!("unary factor references unknown variable {}", var.0);
        }
        if c.rows != self.n || c.cols != self.n || obs.dim() != self.n {
            bail!("unary factor shapes must be n={} (C {}x{}, obs {})",
                self.n, c.rows, c.cols, obs.dim());
        }
        let id = FactorId(self.factors.len());
        self.factors.push(Factor::Unary { var, c, obs });
        self.unary_idx[var.0].push(id);
        Ok(id)
    }

    /// Add a pairwise link `x_to = A x_from + w`, `w ~ N(b, Q)`.
    pub fn add_pairwise(
        &mut self,
        from: VarId,
        to: VarId,
        a: CMatrix,
        noise: GaussMessage,
    ) -> Result<FactorId> {
        if from.0 >= self.vars.len() || to.0 >= self.vars.len() {
            bail!("pairwise factor references unknown variable");
        }
        if from == to {
            bail!("pairwise factor must connect two distinct variables");
        }
        if a.rows != self.n || a.cols != self.n || noise.dim() != self.n {
            bail!("pairwise factor shapes must be n={}", self.n);
        }
        let a_inv = a
            .inverse()
            .context("pairwise state matrix A must be invertible (reverse message)")?;
        let id = FactorId(self.factors.len());
        self.factors.push(Factor::Pairwise { from, to, a, a_inv, noise });
        self.pairwise_idx[from.0].push(id);
        self.pairwise_idx[to.0].push(id);
        Ok(id)
    }

    /// Add a nonlinear observation factor `z = h(x) + v`.
    pub fn add_nonlinear_unary(&mut self, var: VarId, f: NonlinearFactor) -> Result<FactorId> {
        if var.0 >= self.vars.len() {
            bail!("nonlinear unary factor references unknown variable {}", var.0);
        }
        if f.n != self.n {
            bail!("nonlinear factor has n={} but the model is n={}", f.n, self.n);
        }
        let id = FactorId(self.factors.len());
        self.factors.push(Factor::NonlinearUnary { var, f });
        self.unary_idx[var.0].push(id);
        Ok(id)
    }

    /// Add a nonlinear relative factor `z = h(x_from, x_to) + v`.
    pub fn add_nonlinear_pairwise(
        &mut self,
        from: VarId,
        to: VarId,
        f: PairwiseNonlinear,
    ) -> Result<FactorId> {
        if from.0 >= self.vars.len() || to.0 >= self.vars.len() {
            bail!("nonlinear pairwise factor references unknown variable");
        }
        if from == to {
            bail!("nonlinear pairwise factor must connect two distinct variables");
        }
        if f.n != self.n {
            bail!("nonlinear factor has n={} but the model is n={}", f.n, self.n);
        }
        let id = FactorId(self.factors.len());
        self.factors.push(Factor::NonlinearPairwise { from, to, f });
        self.pairwise_idx[from.0].push(id);
        self.pairwise_idx[to.0].push(id);
        Ok(id)
    }

    /// Does the model contain factors that need per-round
    /// relinearization?
    pub fn has_nonlinear(&self) -> bool {
        self.factors.iter().any(|f| {
            matches!(f, Factor::NonlinearUnary { .. } | Factor::NonlinearPairwise { .. })
        })
    }

    /// Pairwise factors incident to `v`, in factor order (O(1) — the
    /// adjacency index is maintained on insert).
    pub fn pairwise_at(&self, v: VarId) -> &[FactorId] {
        &self.pairwise_idx[v.0]
    }

    /// Unary factors at `v`, in factor order (O(1)).
    pub fn unary_at(&self, v: VarId) -> &[FactorId] {
        &self.unary_idx[v.0]
    }

    /// The other endpoint of pairwise factor `f` as seen from `v`.
    pub fn neighbor(&self, f: FactorId, v: VarId) -> Option<VarId> {
        match &self.factors[f.0] {
            Factor::Pairwise { from, to, .. }
            | Factor::NonlinearPairwise { from, to, .. }
                if *from == v =>
            {
                Some(*to)
            }
            Factor::Pairwise { from, to, .. }
            | Factor::NonlinearPairwise { from, to, .. }
                if *to == v =>
            {
                Some(*from)
            }
            _ => None,
        }
    }

    /// Does the model contain a cycle among its pairwise factors?
    /// (Union-find over variable components; a pairwise edge joining two
    /// already-connected variables closes a cycle.)
    pub fn has_cycle(&self) -> bool {
        let mut parent: Vec<usize> = (0..self.vars.len()).collect();
        fn root(parent: &mut [usize], mut i: usize) -> usize {
            while parent[i] != i {
                parent[i] = parent[parent[i]];
                i = parent[i];
            }
            i
        }
        for f in &self.factors {
            if let Factor::Pairwise { from, to, .. }
            | Factor::NonlinearPairwise { from, to, .. } = f
            {
                let (a, b) = (root(&mut parent, from.0), root(&mut parent, to.0));
                if a == b {
                    return true;
                }
                parent[a] = b;
            }
        }
        false
    }

    /// Validate the model for GBP: every variable participates, every
    /// cavity is proper (a variable without a proper prior needs at
    /// least two pairwise factors so that excluding one still leaves a
    /// proper base for the product).
    pub fn validate(&self) -> Result<()> {
        if self.vars.is_empty() {
            bail!("model has no variables");
        }
        for (i, v) in self.vars.iter().enumerate() {
            let deg = self.pairwise_at(VarId(i)).len();
            if v.prior.is_none() && deg == 0 {
                bail!("variable '{}' has neither a prior nor a pairwise factor", v.label);
            }
            if v.prior.is_none() && deg == 1 {
                bail!(
                    "variable '{}' has no prior and only one pairwise factor: \
                     the cavity excluding it is improper",
                    v.label
                );
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Dense information-form reference
    // ------------------------------------------------------------------

    /// Exact marginals by assembling the joint information matrix over
    /// all `num_vars * n` dimensions and inverting it — the reference
    /// loopy GBP is validated against (feasible for test-sized models;
    /// GBP exists precisely because this does not scale). Errors on
    /// models with nonlinear factors, which have no exact Gaussian
    /// joint — use [`GbpModel::dense_marginals_linearized`].
    pub fn dense_marginals(&self) -> Result<Vec<GaussMessage>> {
        if self.has_nonlinear() {
            bail!(
                "model contains nonlinear factors (no exact Gaussian joint); \
                 use dense_marginals_linearized at a linearization point"
            );
        }
        self.dense_assemble(None)
    }

    /// Exact marginals of the model **linearized at the given beliefs**
    /// (one per variable, e.g. a converged GBP solve): every nonlinear
    /// factor is replaced by its `linearizer` stand-in, then the joint
    /// information matrix is assembled and inverted. This is the
    /// conformance reference for nonlinear GBP — at a solver fixed
    /// point, GBP means must match this solve's means.
    pub fn dense_marginals_linearized(
        &self,
        beliefs: &[GaussMessage],
        linearizer: &dyn Linearizer,
    ) -> Result<Vec<GaussMessage>> {
        if beliefs.len() != self.vars.len() {
            bail!(
                "need one linearization belief per variable ({} != {})",
                beliefs.len(),
                self.vars.len()
            );
        }
        self.dense_assemble(Some((beliefs, linearizer)))
    }

    fn dense_assemble(
        &self,
        relin: Option<(&[GaussMessage], &dyn Linearizer)>,
    ) -> Result<Vec<GaussMessage>> {
        let n = self.n;
        let nv = self.vars.len();
        let dim = nv * n;
        let mut w = CMatrix::zeros(dim, dim);
        let mut h = vec![c64::ZERO; dim];

        let add_block = |w: &mut CMatrix, bi: usize, bj: usize, m: &CMatrix| {
            for i in 0..n {
                for j in 0..n {
                    let (r, c) = (bi * n + i, bj * n + j);
                    w[(r, c)] = w[(r, c)] + m[(i, j)];
                }
            }
        };
        let add_vec = |h: &mut Vec<c64>, bi: usize, v: &[c64]| {
            for i in 0..n {
                h[bi * n + i] = h[bi * n + i] + v[i];
            }
        };

        for (i, var) in self.vars.iter().enumerate() {
            if let Some(p) = &var.prior {
                let (wp, wpm) = p
                    .to_weight_form()
                    .with_context(|| format!("prior of '{}' is singular", var.label))?;
                add_block(&mut w, i, i, &wp);
                add_vec(&mut h, i, &wpm);
            }
        }
        let need_relin = |what: &str| -> Result<(&[GaussMessage], &dyn Linearizer)> {
            relin.ok_or_else(|| {
                anyhow::anyhow!("{what} requires linearization beliefs (dense_marginals_linearized)")
            })
        };
        for f in &self.factors {
            match f {
                Factor::Unary { var, c, obs } => {
                    // info: C^H R^{-1} C, vector: C^H R^{-1} y
                    let rinv = obs
                        .cov
                        .inverse()
                        .context("unary observation covariance is singular")?;
                    let ch = c.hermitian();
                    let chr = ch.matmul(&rinv);
                    add_block(&mut w, var.0, var.0, &chr.matmul(c));
                    add_vec(&mut h, var.0, &chr.matvec(&obs.mean));
                }
                Factor::NonlinearUnary { var, f } => {
                    let (beliefs, lz) = need_relin("nonlinear unary factor")?;
                    let lin = lz.linearize(f, &beliefs[var.0])?;
                    let rinv = lin
                        .obs
                        .cov
                        .inverse()
                        .context("linearized observation covariance is singular")?;
                    let chr = lin.a.hermitian().matmul(&rinv);
                    add_block(&mut w, var.0, var.0, &chr.matmul(&lin.a));
                    add_vec(&mut h, var.0, &chr.matvec(&lin.obs.mean));
                }
                Factor::NonlinearPairwise { from, to, f } => {
                    // linearized: z_eff = A_f x_f + A_t x_t + v
                    let (beliefs, lz) = need_relin("nonlinear pairwise factor")?;
                    let pr = f.linearize_with(lz, &beliefs[from.0], &beliefs[to.0])?;
                    let rinv = pr
                        .obs
                        .cov
                        .inverse()
                        .context("linearized pairwise covariance is singular")?;
                    let afr = pr.a_from.hermitian().matmul(&rinv);
                    let atr = pr.a_to.hermitian().matmul(&rinv);
                    add_block(&mut w, from.0, from.0, &afr.matmul(&pr.a_from));
                    add_block(&mut w, from.0, to.0, &afr.matmul(&pr.a_to));
                    add_block(&mut w, to.0, from.0, &atr.matmul(&pr.a_from));
                    add_block(&mut w, to.0, to.0, &atr.matmul(&pr.a_to));
                    add_vec(&mut h, from.0, &afr.matvec(&pr.obs.mean));
                    add_vec(&mut h, to.0, &atr.matvec(&pr.obs.mean));
                }
                Factor::Pairwise { from, to, a, noise, .. } => {
                    // residual r = x_to - A x_from - b ~ N(0, Q):
                    //   W += J^H Q^{-1} J with J = [-A  I] over (from,to)
                    //   h += J^H Q^{-1} b
                    let qinv = noise
                        .cov
                        .inverse()
                        .context("pairwise noise covariance is singular")?;
                    let ah = a.hermitian();
                    let ahq = ah.matmul(&qinv);
                    add_block(&mut w, from.0, from.0, &ahq.matmul(a));
                    add_block(&mut w, from.0, to.0, &ahq.neg());
                    add_block(&mut w, to.0, from.0, &qinv.matmul(a).neg());
                    add_block(&mut w, to.0, to.0, &qinv);
                    let qb = qinv.matvec(&noise.mean);
                    add_vec(&mut h, to.0, &qb);
                    let minus_ahqb: CVector = ah.matvec(&qb).iter().map(|z| -*z).collect();
                    add_vec(&mut h, from.0, &minus_ahqb);
                }
            }
        }

        let v = w
            .inverse()
            .context("joint information matrix is singular (model under-constrained)")?;
        // one factorization serves both: the joint mean is V·h
        let mut hm = CMatrix::zeros(dim, 1);
        for (i, z) in h.iter().enumerate() {
            hm[(i, 0)] = *z;
        }
        let mean = v.matmul(&hm);

        let mut out = Vec::with_capacity(nv);
        for b in 0..nv {
            let m: CVector = (0..n).map(|i| mean[(b * n + i, 0)]).collect();
            let mut cov = CMatrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    cov[(i, j)] = v[(b * n + i, b * n + j)];
                }
            }
            out.push(GaussMessage::new(m, cov));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::nodes;
    use crate::testutil::Rng;

    fn obs_proj(n: usize) -> CMatrix {
        let mut c = CMatrix::zeros(n, n);
        c[(0, 0)] = c64::ONE;
        c
    }

    #[test]
    fn validation_rejects_improper_cavities() {
        let n = 4;
        let mut m = GbpModel::new(n);
        let a = m.add_variable(None, "a").unwrap();
        let b = m.add_variable(Some(GaussMessage::isotropic(n, 1.0)), "b").unwrap();
        m.add_pairwise(a, b, CMatrix::identity(n), GaussMessage::isotropic(n, 0.1)).unwrap();
        // 'a' has no prior and degree 1: the cavity excluding its only
        // pairwise factor is improper
        let err = m.validate().unwrap_err();
        assert!(format!("{err:#}").contains("improper"), "{err:#}");
    }

    #[test]
    fn singular_a_is_rejected() {
        let n = 4;
        let mut m = GbpModel::new(n);
        let a = m.add_variable(Some(GaussMessage::isotropic(n, 1.0)), "a").unwrap();
        let b = m.add_variable(Some(GaussMessage::isotropic(n, 1.0)), "b").unwrap();
        let err = m
            .add_pairwise(a, b, CMatrix::zeros(n, n), GaussMessage::isotropic(n, 0.1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("invertible"), "{err:#}");
    }

    #[test]
    fn cycle_detection() {
        let n = 4;
        let prior = || Some(GaussMessage::isotropic(n, 1.0));
        let noise = || GaussMessage::isotropic(n, 0.1);
        let mut m = GbpModel::new(n);
        let a = m.add_variable(prior(), "a").unwrap();
        let b = m.add_variable(prior(), "b").unwrap();
        let c = m.add_variable(prior(), "c").unwrap();
        m.add_pairwise(a, b, CMatrix::identity(n), noise()).unwrap();
        m.add_pairwise(b, c, CMatrix::identity(n), noise()).unwrap();
        assert!(!m.has_cycle());
        m.add_pairwise(c, a, CMatrix::identity(n), noise()).unwrap();
        assert!(m.has_cycle());
    }

    #[test]
    fn dense_single_variable_is_prior_times_observation() {
        // one variable, one full-rank unary: the dense marginal must be
        // the golden compound-observation update (A = C = I)
        let mut rng = Rng::new(3);
        let n = 4;
        let prior = GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(&mut rng, n, 1.0).scale(0.2),
        );
        let obs = GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(&mut rng, n, 1.0).scale(0.2),
        );
        let mut m = GbpModel::new(n);
        let v = m.add_variable(Some(prior.clone()), "x").unwrap();
        m.add_unary(v, CMatrix::identity(n), obs.clone()).unwrap();
        let marg = m.dense_marginals().unwrap();
        let want = nodes::compound_observation(&prior, &obs, &CMatrix::identity(n), false).unwrap();
        assert!(marg[0].dist(&want) < 1e-9, "dist {}", marg[0].dist(&want));
    }

    #[test]
    fn dense_rank_deficient_unary_only_informs_observed_row() {
        let n = 4;
        let mut m = GbpModel::new(n);
        let prior = GaussMessage::isotropic(n, 1.0);
        let v = m.add_variable(Some(prior.clone()), "x").unwrap();
        let mut y = vec![c64::ZERO; n];
        y[0] = c64::new(0.3, 0.0);
        m.add_unary(v, obs_proj(n), GaussMessage::new(y, CMatrix::scaled_identity(n, 0.1)))
            .unwrap();
        let marg = m.dense_marginals().unwrap();
        // observed component tightens, unobserved stay at the prior
        assert!(marg[0].cov[(1, 1)].re > 0.99);
        assert!(marg[0].cov[(0, 0)].re < 0.12);
        assert!((marg[0].mean[0].re - 0.3 / 1.1 * 1.0).abs() < 0.05);
        assert!(marg[0].mean[1].abs() < 1e-12);
    }

    #[test]
    fn dense_pairwise_carries_offset() {
        // x1 anchored at 0; x2 = x1 + b: marginal mean of x2 is b
        let n = 4;
        let mut m = GbpModel::new(n);
        let x1 = m
            .add_variable(Some(GaussMessage::isotropic(n, 1e-6)), "x1")
            .unwrap();
        let x2 = m.add_variable(Some(GaussMessage::isotropic(n, 10.0)), "x2").unwrap();
        let mut b = vec![c64::ZERO; n];
        b[0] = c64::new(0.25, -0.1);
        m.add_pairwise(
            x1,
            x2,
            CMatrix::identity(n),
            GaussMessage::new(b.clone(), CMatrix::scaled_identity(n, 0.01)),
        )
        .unwrap();
        let marg = m.dense_marginals().unwrap();
        assert!((marg[1].mean[0] - b[0]).abs() < 1e-2, "{}", marg[1].mean[0]);
    }
}
