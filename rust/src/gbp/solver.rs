//! The loopy-GBP solver: policies × bridge × convergence monitor.
//!
//! [`GbpSolver`] owns the message state and the iteration loop; every
//! inner update (factor-to-variable messages *and* variable-belief
//! products) is lowered by [`super::bridge`] and executed by a
//! [`RoundExecutor`] — one [`crate::engine::Session`] on any engine, or
//! a [`crate::coordinator::FgpFarm`] sharding each round across
//! devices. The solver itself never evaluates a node rule.
//!
//! On tree graphs the fixed point is exact (identical to the scheduled
//! sweeps the compiler serves); on cyclic graphs the fixed-point
//! **means** are exact and the covariances are approximate (Weiss &
//! Freeman 2001) — the conformance tests encode precisely that
//! contract against the dense information-form solve.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::gmp::message::GaussMessage;
use crate::nonlinear::{FirstOrder, Linearizer};

use super::bridge::{
    belief_request, directed_edges, edge_request, BuiltRequest, EdgeKey, MessageState,
    RelinContext, RoundExecutor,
};
use super::model::{GbpModel, VarId};
use super::policy::{damp, ConvergenceCriteria, ConvergenceMonitor, IterationPolicy, StopReason};

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct GbpOptions {
    /// Which edges update per round, and how proposals commit.
    pub policy: IterationPolicy,
    /// Stopping criteria (tolerance, max iterations, divergence).
    pub criteria: ConvergenceCriteria,
    /// Variance of the vague zero-mean messages every edge starts from.
    pub init_var: f64,
    /// Variance of the vague zero-mean base each **nonlinear pairwise**
    /// likelihood message is grafted onto (the linearized stand-in is
    /// generally rank-deficient, so its moment-form message needs a
    /// proper base). The base injects `1/nonlinear_base_var` of
    /// spurious information per message that the dense linearized
    /// reference does not model — keep it large relative to the
    /// factors' information so the bias stays inside the conformance
    /// tolerance. Deliberately independent of `init_var`.
    pub nonlinear_base_var: f64,
}

impl Default for GbpOptions {
    fn default() -> Self {
        GbpOptions {
            policy: IterationPolicy::default(),
            criteria: ConvergenceCriteria::default(),
            init_var: 10.0,
            nonlinear_base_var: 10.0,
        }
    }
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct GbpReport {
    /// Posterior marginal per variable, in variable order.
    pub beliefs: Vec<GaussMessage>,
    /// Iterations executed.
    pub iterations: usize,
    /// Why the solver stopped.
    pub stop: StopReason,
    /// Belief delta of the last iteration.
    pub final_delta: f64,
    /// Belief delta per iteration.
    pub delta_history: Vec<f64>,
    /// Directed-edge messages computed over the whole solve.
    pub messages_sent: usize,
    /// Variable-belief products computed over the whole solve (the
    /// bookkeeping cost next to `messages_sent`; residual scheduling
    /// only refreshes beliefs its batch actually touched).
    pub beliefs_computed: usize,
}

impl GbpReport {
    /// True when the solver reached the belief-delta tolerance.
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }

    /// Posterior marginals in variable order — the evidence surface an
    /// EM E-step ([`crate::em`]) consumes: on tree models the beliefs
    /// are exact marginals, so EM over them is exact; on cyclic models
    /// the means are exact and covariances approximate (Weiss & Freeman
    /// 2001), which EM inherits.
    pub fn marginals(&self) -> &[GaussMessage] {
        &self.beliefs
    }
}

/// Iterative Gaussian belief propagation over a [`GbpModel`].
pub struct GbpSolver {
    model: GbpModel,
    opts: GbpOptions,
    state: MessageState,
    edges: Vec<EdgeKey>,
    /// Residual-policy priorities, aligned with `edges`.
    priorities: Vec<f64>,
    beliefs: Vec<GaussMessage>,
    monitor: ConvergenceMonitor,
    /// Linearizer for the model's nonlinear factors (EKF-style
    /// first-order by default; sigma-point via
    /// [`GbpSolver::with_linearizer`]).
    linearizer: Arc<dyn Linearizer>,
    /// Current round's linearizations (empty for linear models).
    relin: RelinContext,
    messages_sent: usize,
    beliefs_computed: usize,
}

impl GbpSolver {
    /// Solver with the default first-order (EKF) linearizer.
    pub fn new(model: GbpModel, opts: GbpOptions) -> Result<Self> {
        Self::with_linearizer(model, opts, Arc::new(FirstOrder))
    }

    /// Build a solver with an explicit [`Linearizer`] for the model's
    /// nonlinear factors (relinearized at the current beliefs every
    /// round — Ortiz et al. 2021).
    pub fn with_linearizer(
        model: GbpModel,
        opts: GbpOptions,
        linearizer: Arc<dyn Linearizer>,
    ) -> Result<Self> {
        model.validate()?;
        if model.has_nonlinear() && matches!(opts.policy, IterationPolicy::Residual { .. }) {
            // residual priorities track message deltas, not
            // linearization-point movement; relinearization would
            // invalidate quiescence
            bail!("nonlinear factors require the synchronous iteration policy");
        }
        let state = MessageState::vague(&model, opts.init_var);
        let edges = directed_edges(&model);
        let priorities = vec![f64::INFINITY; edges.len()];
        let monitor = ConvergenceMonitor::new(opts.criteria);
        Ok(GbpSolver {
            model,
            opts,
            state,
            edges,
            priorities,
            beliefs: Vec::new(),
            monitor,
            linearizer,
            relin: RelinContext::empty(),
            messages_sent: 0,
            beliefs_computed: 0,
        })
    }

    /// The model being solved.
    pub fn model(&self) -> &GbpModel {
        &self.model
    }

    /// Current factor→variable message state (bitwise comparable across
    /// executors).
    pub fn state(&self) -> &MessageState {
        &self.state
    }

    /// Latest computed beliefs (empty before the first iteration).
    pub fn beliefs(&self) -> &[GaussMessage] {
        &self.beliefs
    }

    /// Alias of [`GbpSolver::beliefs`] naming the EM-facing contract:
    /// the solver's beliefs are the posterior marginals an E-step
    /// consumes (see [`GbpReport::marginals`]).
    pub fn marginals(&self) -> &[GaussMessage] {
        &self.beliefs
    }

    /// Directed-edge messages computed so far.
    pub fn messages_sent(&self) -> usize {
        self.messages_sent
    }

    /// Relinearize the model's nonlinear factors at the current beliefs
    /// (the priors / vague init before the first round). A no-op for
    /// linear models.
    fn relinearize(&mut self) -> Result<()> {
        if !self.model.has_nonlinear() {
            return Ok(());
        }
        let lin_beliefs: Vec<GaussMessage> = (0..self.model.num_vars())
            .map(|v| {
                self.beliefs.get(v).cloned().unwrap_or_else(|| {
                    self.model
                        .variable(VarId(v))
                        .prior
                        .clone()
                        .unwrap_or_else(|| {
                            GaussMessage::isotropic(self.model.n(), self.opts.init_var)
                        })
                })
            })
            .collect();
        self.relin = RelinContext::relinearize(
            &self.model,
            &lin_beliefs,
            &*self.linearizer,
            self.opts.nonlinear_base_var,
        )?;
        Ok(())
    }

    /// Run to convergence (or max-iters / divergence).
    pub fn run(&mut self, exec: &mut dyn RoundExecutor) -> Result<GbpReport> {
        let nonlinear = self.model.has_nonlinear();
        // baseline beliefs from the initial messages (not an iteration)
        if self.beliefs.is_empty() {
            self.relinearize()?;
            let all: Vec<VarId> = (0..self.model.num_vars()).map(VarId).collect();
            self.beliefs = vec![GaussMessage::isotropic(self.model.n(), 0.0); all.len()];
            self.refresh_beliefs(exec, &all)?;
        }
        let stop = loop {
            // nonlinear factors relinearize at the beliefs entering the
            // round — the relinearize → run → update-point sweep
            self.relinearize()?;
            let (quiescent, touched) = self.step_round(exec)?;
            // only beliefs of variables whose incoming messages changed
            // can move; everything else contributes zero delta — except
            // under relinearization, which moves every factor
            let refresh: Vec<VarId> = if nonlinear {
                (0..self.model.num_vars()).map(VarId).collect()
            } else {
                touched
            };
            let delta = self.refresh_beliefs(exec, &refresh)?;
            if let Some(reason) = self.monitor.observe(delta, quiescent) {
                break reason;
            }
        };
        Ok(GbpReport {
            beliefs: self.beliefs.clone(),
            iterations: self.monitor.iterations(),
            stop,
            final_delta: self.monitor.final_delta(),
            delta_history: self.monitor.history.clone(),
            messages_sent: self.messages_sent,
            beliefs_computed: self.beliefs_computed,
        })
    }

    /// One message iteration (round or residual batch). Returns whether
    /// the policy has no further work queued, plus the variables whose
    /// incoming messages changed (their beliefs need refreshing).
    fn step_round(&mut self, exec: &mut dyn RoundExecutor) -> Result<(bool, Vec<VarId>)> {
        match self.opts.policy {
            IterationPolicy::Synchronous { eta_damping } => {
                self.sync_round(exec, eta_damping)?;
                let all = (0..self.model.num_vars()).map(VarId).collect();
                Ok((true, all))
            }
            IterationPolicy::Residual { batch, eta_damping } => {
                self.residual_batch(exec, batch.max(1), eta_damping)
            }
        }
    }

    /// Recompute the beliefs of `vars` through the executor, updating
    /// them in place; returns the max belief delta over the refreshed
    /// set (untouched beliefs are unchanged by construction).
    fn refresh_beliefs(&mut self, exec: &mut dyn RoundExecutor, vars: &[VarId]) -> Result<f64> {
        let mut pending = Vec::new();
        let mut pending_vars = Vec::new();
        let mut delta = 0.0_f64;
        for v in vars {
            match belief_request(&self.model, &self.state, &self.relin, *v)
                .with_context(|| format!("belief of variable {}", v.0))?
            {
                BuiltRequest::Trivial(m) => {
                    delta = delta.max(self.beliefs[v.0].dist(&m));
                    self.beliefs[v.0] = m;
                }
                BuiltRequest::Run(req) => {
                    pending.push(req);
                    pending_vars.push(*v);
                }
            }
        }
        let results = exec.run_batch(&pending).context("belief round")?;
        self.beliefs_computed += vars.len();
        for (v, m) in pending_vars.into_iter().zip(results) {
            delta = delta.max(self.beliefs[v.0].dist(&m));
            self.beliefs[v.0] = m;
        }
        Ok(delta)
    }

    /// Jacobi round: every directed edge updates from the pre-round
    /// state, then all messages commit (damped).
    fn sync_round(&mut self, exec: &mut dyn RoundExecutor, eta: f64) -> Result<()> {
        let mut reqs = Vec::with_capacity(self.edges.len());
        for e in &self.edges {
            match edge_request(&self.model, &self.state, &self.relin, *e)
                .with_context(|| format!("edge update for factor {}", e.factor.0))?
            {
                BuiltRequest::Run(req) => reqs.push(req),
                BuiltRequest::Trivial(_) => unreachable!("edge transforms always have nodes"),
            }
        }
        let proposed = exec.run_batch(&reqs).context("message round")?;
        for (e, new) in self.edges.clone().into_iter().zip(proposed) {
            let damped = damp(self.state.get(e), &new, eta)?;
            self.state.set(e, damped);
        }
        self.messages_sent += self.edges.len();
        Ok(())
    }

    /// Residual-priority ("wildfire") batch: the highest-priority edges
    /// update sequentially-greedily; their residuals re-prime the
    /// priorities of downstream edges. Returns true when no edge has
    /// priority above the convergence tolerance (quiescent).
    fn residual_batch(
        &mut self,
        exec: &mut dyn RoundExecutor,
        batch: usize,
        eta: f64,
    ) -> Result<(bool, Vec<VarId>)> {
        let tol = self.opts.criteria.tol;
        let mut order: Vec<usize> = (0..self.edges.len())
            .filter(|i| self.priorities[*i] > tol)
            .collect();
        if order.is_empty() {
            return Ok((true, Vec::new()));
        }
        order.sort_by(|a, b| {
            self.priorities[*b]
                .partial_cmp(&self.priorities[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        });
        order.truncate(batch);

        let mut reqs = Vec::with_capacity(order.len());
        for i in &order {
            match edge_request(&self.model, &self.state, &self.relin, self.edges[*i])? {
                BuiltRequest::Run(req) => reqs.push(req),
                BuiltRequest::Trivial(_) => unreachable!("edge transforms always have nodes"),
            }
        }
        let proposed = exec.run_batch(&reqs).context("residual batch")?;
        // clear the selected priorities BEFORE re-priming: proposals were
        // computed from the pre-batch state, so an edge committed later
        // in this batch must keep the priming an earlier commit gave it
        // (zeroing inside the commit loop would wipe it and could declare
        // convergence on a stale message)
        for i in &order {
            self.priorities[*i] = 0.0;
        }
        let mut touched = Vec::with_capacity(order.len());
        for (i, new) in order.iter().zip(proposed) {
            let e = self.edges[*i];
            let old = self.state.get(e).clone();
            let damped = damp(&old, &new, eta)?;
            let residual = damped.dist(&old);
            self.state.set(e, damped);
            // residual flows to the edges leaving the target variable
            let target = e.target(&self.model);
            if !touched.contains(&target) {
                touched.push(target);
            }
            for (j, other) in self.edges.iter().enumerate() {
                if other.factor != e.factor && other.source(&self.model) == target {
                    self.priorities[j] += residual;
                }
            }
        }
        self.messages_sent += order.len();
        Ok((self.priorities.iter().all(|p| *p <= tol), touched))
    }
}

/// Max over variables of the per-belief max-abs change.
pub fn belief_delta(old: &[GaussMessage], new: &[GaussMessage]) -> f64 {
    old.iter()
        .zip(new)
        .map(|(o, n)| o.dist(n))
        .fold(0.0, f64::max)
}

/// One-call convenience: build, run, report (nonlinear factors, if any,
/// relinearize with the first-order/EKF linearizer).
pub fn solve(
    model: GbpModel,
    opts: GbpOptions,
    exec: &mut dyn RoundExecutor,
) -> Result<GbpReport> {
    GbpSolver::new(model, opts)?.run(exec)
}

/// [`solve`] with an explicit linearizer for nonlinear factors.
pub fn solve_with_linearizer(
    model: GbpModel,
    opts: GbpOptions,
    linearizer: Arc<dyn Linearizer>,
    exec: &mut dyn RoundExecutor,
) -> Result<GbpReport> {
    GbpSolver::with_linearizer(model, opts, linearizer)?.run(exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Session;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::Rng;

    fn proper(rng: &mut Rng, n: usize) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.2),
        )
    }

    fn ring_model(rng: &mut Rng, n: usize, vars: usize) -> GbpModel {
        let mut m = GbpModel::new(n);
        let ids: Vec<_> = (0..vars)
            .map(|i| m.add_variable(Some(proper(rng, n)), format!("x{i}")).unwrap())
            .collect();
        for i in 0..vars {
            m.add_pairwise(
                ids[i],
                ids[(i + 1) % vars],
                CMatrix::identity(n),
                GaussMessage::isotropic(n, 0.2),
            )
            .unwrap();
        }
        m
    }

    #[test]
    fn two_var_tree_converges_to_dense_marginals() {
        let mut rng = Rng::new(1);
        let n = 4;
        let mut m = GbpModel::new(n);
        let a = m.add_variable(Some(proper(&mut rng, n)), "a").unwrap();
        let b = m.add_variable(Some(proper(&mut rng, n)), "b").unwrap();
        m.add_pairwise(a, b, CMatrix::identity(n), GaussMessage::isotropic(n, 0.1))
            .unwrap();
        let dense = m.dense_marginals().unwrap();
        let report = solve(m, GbpOptions::default(), &mut Session::golden()).unwrap();
        assert!(report.converged(), "{:?}", report.stop);
        assert!(report.iterations <= 5, "tree of depth 1 must converge fast");
        for (got, want) in report.beliefs.iter().zip(&dense) {
            assert!(got.dist(want) < 1e-9, "dist {}", got.dist(want));
        }
    }

    #[test]
    fn ring_is_cyclic_and_converges_with_exact_means() {
        let mut rng = Rng::new(2);
        let model = ring_model(&mut rng, 4, 4);
        assert!(model.has_cycle());
        let dense = model.dense_marginals().unwrap();
        let report = solve(model, GbpOptions::default(), &mut Session::golden()).unwrap();
        assert!(report.converged(), "stop {:?} after {} iters", report.stop, report.iterations);
        // loopy GBP: means exact at the fixed point, covariances
        // approximate (Weiss & Freeman 2001)
        for (got, want) in report.beliefs.iter().zip(&dense) {
            let mean_err = got
                .mean
                .iter()
                .zip(&want.mean)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(mean_err < 1e-5, "mean err {mean_err}");
            assert!(got.cov.dist(&want.cov) < 0.2, "cov err {}", got.cov.dist(&want.cov));
        }
    }

    #[test]
    fn damping_still_reaches_the_same_fixed_point() {
        let mut rng = Rng::new(3);
        let model = ring_model(&mut rng, 4, 5);
        let undamped = solve(
            model.clone(),
            GbpOptions::default(),
            &mut Session::golden(),
        )
        .unwrap();
        let damped = solve(
            model,
            GbpOptions {
                policy: IterationPolicy::Synchronous { eta_damping: 0.4 },
                ..Default::default()
            },
            &mut Session::golden(),
        )
        .unwrap();
        assert!(damped.converged());
        let d = belief_delta(&undamped.beliefs, &damped.beliefs);
        assert!(d < 1e-5, "fixed points differ by {d}");
    }

    #[test]
    fn residual_policy_matches_synchronous_fixed_point() {
        let mut rng = Rng::new(4);
        let model = ring_model(&mut rng, 4, 4);
        let sync = solve(model.clone(), GbpOptions::default(), &mut Session::golden()).unwrap();
        let residual = solve(
            model,
            GbpOptions {
                policy: IterationPolicy::Residual { batch: 3, eta_damping: 0.0 },
                criteria: ConvergenceCriteria { max_iters: 400, ..Default::default() },
                ..Default::default()
            },
            &mut Session::golden(),
        )
        .unwrap();
        assert!(residual.converged(), "stop {:?}", residual.stop);
        let d = belief_delta(&sync.beliefs, &residual.beliefs);
        assert!(d < 1e-5, "policies disagree by {d}");
        assert!(residual.messages_sent > 0);
    }

    #[test]
    fn residual_full_batch_does_not_converge_prematurely() {
        // batch == every directed edge: each batch pairs upstream and
        // downstream edges, the regression case for the same-batch
        // priority wipe (priming from an earlier commit must survive a
        // later commit's priority reset)
        let mut rng = Rng::new(7);
        let model = ring_model(&mut rng, 4, 4);
        let dense = model.dense_marginals().unwrap();
        let report = solve(
            model,
            GbpOptions {
                policy: IterationPolicy::Residual { batch: 8, eta_damping: 0.0 },
                criteria: ConvergenceCriteria { tol: 1e-8, max_iters: 200, divergence: 1e6 },
                ..Default::default()
            },
            &mut Session::golden(),
        )
        .unwrap();
        assert!(report.converged(), "stop {:?}", report.stop);
        for (got, want) in report.beliefs.iter().zip(&dense) {
            let mean_err = got
                .mean
                .iter()
                .zip(&want.mean)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(mean_err < 1e-6, "premature convergence: mean err {mean_err}");
        }
    }

    #[test]
    fn report_carries_history_and_counts() {
        let mut rng = Rng::new(5);
        let model = ring_model(&mut rng, 4, 3);
        let edges = 2 * 3; // three pairwise factors, two directions
        let report = solve(model, GbpOptions::default(), &mut Session::golden()).unwrap();
        assert_eq!(report.delta_history.len(), report.iterations);
        assert_eq!(report.messages_sent, edges * report.iterations);
        // synchronous rounds refresh every belief, plus the baseline
        assert_eq!(report.beliefs_computed, 3 * (report.iterations + 1));
        assert_eq!(report.final_delta, *report.delta_history.last().unwrap());
    }

    #[test]
    fn max_iters_is_reported_not_spun() {
        let mut rng = Rng::new(6);
        let model = ring_model(&mut rng, 4, 4);
        let report = solve(
            model,
            GbpOptions {
                criteria: ConvergenceCriteria { tol: 0.0, max_iters: 3, divergence: 1e6 },
                ..Default::default()
            },
            &mut Session::golden(),
        )
        .unwrap();
        assert_eq!(report.stop, StopReason::MaxIters);
        assert_eq!(report.iterations, 3);
    }
}
