//! S13 — Loopy Gaussian Belief Propagation over the engine surface.
//!
//! The paper's compiler serves *scheduled* GMP sweeps over
//! tree-structured graphs (§III–IV); an entire class of workloads —
//! grid smoothing, pose graphs, distributed estimation — lives on
//! graphs **with cycles**, where no finite schedule is exact and
//! inference is iterative (Ortiz et al., *A visual introduction to
//! Gaussian Belief Propagation*, 2021). This subsystem serves those
//! graphs while still running every inner update on the paper's device:
//!
//! * [`model`] — the cyclic-capable variable/factor view ([`GbpModel`])
//!   with priors, unary observations and invertible linear-Gaussian
//!   pairwise links — plus **nonlinear** unary/pairwise factors
//!   ([`crate::nonlinear`]) that the solver relinearizes at the current
//!   beliefs every round (Ortiz et al. 2021) — and the exact dense
//!   information-form solve used as the conformance reference
//!   (linearized-at-a-point variant for nonlinear models);
//! * [`policy`] — pluggable iteration policies (synchronous/Jacobi
//!   rounds, damped updates via `eta_damping`, residual-priority
//!   "wildfire" scheduling) and the convergence monitor (belief-delta
//!   norm, max-iters, divergence detection);
//! * [`bridge`] — lowers each directed-edge update and each belief
//!   product onto a small scheduled [`crate::gmp::FactorGraph`]
//!   (Gaussian products are compound-observation nodes with identity
//!   states; pairwise transforms are multiplier+adder nodes) and
//!   executes batches through any [`crate::engine::Session`] or a
//!   [`crate::coordinator::FgpFarm`] sharding a round across devices;
//! * [`solver`] — the iteration loop ([`GbpSolver`]) and its report.
//!
//! ```
//! use fgp_repro::engine::Session;
//! use fgp_repro::gbp::{solve, GbpModel, GbpOptions};
//! use fgp_repro::gmp::matrix::CMatrix;
//! use fgp_repro::gmp::message::GaussMessage;
//!
//! // a two-variable tree: a proper prior on each, one identity link
//! let n = 4;
//! let mut model = GbpModel::new(n);
//! let a = model.add_variable(Some(GaussMessage::isotropic(n, 1.0)), "a").unwrap();
//! let b = model.add_variable(Some(GaussMessage::isotropic(n, 2.0)), "b").unwrap();
//! model
//!     .add_pairwise(a, b, CMatrix::identity(n), GaussMessage::isotropic(n, 0.1))
//!     .unwrap();
//!
//! // on a tree the GBP fixed point equals the exact dense marginals
//! let dense = model.dense_marginals().unwrap();
//! let report = solve(model, GbpOptions::default(), &mut Session::golden()).unwrap();
//! assert!(report.converged());
//! for (belief, exact) in report.marginals().iter().zip(&dense) {
//!     assert!(belief.dist(exact) < 1e-9);
//! }
//! ```
//!
//! Contract, pinned by `rust/tests/integration_gbp.rs` and
//! `rust/tests/property_gbp.rs`:
//!
//! 1. on **tree** graphs the converged beliefs equal the scheduled-sweep
//!    golden result (same factorization, same arithmetic, ≤ 1e-9);
//! 2. on **cyclic** graphs the converged means match the dense solve
//!    (exact-means property of Gaussian BP), covariances within the
//!    workload tolerance;
//! 3. a round sharded over an `FgpFarm` is **bitwise identical** to the
//!    same round on a single device (requests are self-contained and
//!    the simulator is deterministic).

pub mod bridge;
pub mod model;
pub mod policy;
pub mod solver;

pub use bridge::{
    belief_request, directed_edges, edge_request, BuiltRequest, Direction, EdgeKey,
    FarmExecutor, MessageState, RelinContext, RoundExecutor,
};
pub use model::{Factor, FactorId, GbpModel, VarId, Variable};
pub use policy::{
    damp, ConvergenceCriteria, ConvergenceMonitor, IterationPolicy, StopReason,
};
pub use solver::{
    belief_delta, solve, solve_with_linearizer, GbpOptions, GbpReport, GbpSolver,
};
