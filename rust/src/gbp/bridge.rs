//! The execution bridge: GBP inner updates as engine workloads.
//!
//! Loopy GBP's inner kernel — fuse a cavity product, push it through a
//! linear-Gaussian factor — is exactly the node vocabulary the paper's
//! device executes: the moment-form Gaussian product is a compound
//! observation with an identity state (the trick
//! [`crate::apps::smoother`] already uses for marginal fusion),
//! observation conditioning is a compound observation with the factor's
//! `C`, and the pairwise transform is a multiplier plus an adder. Each
//! directed-edge update therefore lowers to a small scheduled
//! [`FactorGraph`] and ships as a [`WorkloadRequest`] through **any**
//! engine: the f64 golden rules, the cycle-accurate FGP simulator, the
//! XLA runtime, or a whole [`FgpFarm`] sharding the round across
//! devices.
//!
//! Requests are self-contained and deterministic, so a round sharded
//! over N devices produces **bitwise-identical** messages to the same
//! round on one device — the property
//! `rust/tests/integration_gbp.rs` pins.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{FgpFarm, WorkloadRequest};
use crate::engine::Session;
use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;
use crate::gmp::{EdgeId, FactorGraph, MsgId, NodeKind, Schedule};
use crate::nonlinear::{Linearization, Linearizer, PairRelin};

use super::model::{Factor, FactorId, GbpModel, VarId};

/// Direction of a pairwise factor's message.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Message towards the factor's `to` endpoint.
    Forward,
    /// Message towards the factor's `from` endpoint.
    Backward,
}

/// One directed edge of the GBP message graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EdgeKey {
    /// The factor whose message this is.
    pub factor: FactorId,
    /// Which way along the factor the message flows.
    pub dir: Direction,
}

impl EdgeKey {
    /// Variable this edge's message is *sent to*.
    pub fn target(&self, model: &GbpModel) -> VarId {
        match (model.factor(self.factor), self.dir) {
            (
                Factor::Pairwise { to, .. } | Factor::NonlinearPairwise { to, .. },
                Direction::Forward,
            ) => *to,
            (
                Factor::Pairwise { from, .. } | Factor::NonlinearPairwise { from, .. },
                Direction::Backward,
            ) => *from,
            _ => unreachable!("edge keys only index pairwise factors"),
        }
    }

    /// Variable whose cavity feeds this edge's update.
    pub fn source(&self, model: &GbpModel) -> VarId {
        match (model.factor(self.factor), self.dir) {
            (
                Factor::Pairwise { from, .. } | Factor::NonlinearPairwise { from, .. },
                Direction::Forward,
            ) => *from,
            (
                Factor::Pairwise { to, .. } | Factor::NonlinearPairwise { to, .. },
                Direction::Backward,
            ) => *to,
            _ => unreachable!("edge keys only index pairwise factors"),
        }
    }
}

/// All directed edges of a model, in deterministic (factor, direction)
/// order — the order every synchronous round uses.
pub fn directed_edges(model: &GbpModel) -> Vec<EdgeKey> {
    let mut out = Vec::new();
    for (i, f) in model.factors().iter().enumerate() {
        if matches!(f, Factor::Pairwise { .. } | Factor::NonlinearPairwise { .. }) {
            out.push(EdgeKey { factor: FactorId(i), dir: Direction::Forward });
            out.push(EdgeKey { factor: FactorId(i), dir: Direction::Backward });
        }
    }
    out
}

/// Current factor→variable messages, indexed by pairwise factor id.
#[derive(Clone, Debug)]
pub struct MessageState {
    /// Message towards `to`, per factor (identity placeholder on unary
    /// factor ids, never read).
    pub forward: Vec<GaussMessage>,
    /// Message towards `from`, per factor.
    pub backward: Vec<GaussMessage>,
}

impl MessageState {
    /// Vague initialization: every pairwise message starts as a
    /// zero-mean isotropic Gaussian with variance `init_var`.
    pub fn vague(model: &GbpModel, init_var: f64) -> Self {
        let m = GaussMessage::isotropic(model.n(), init_var);
        MessageState {
            forward: vec![m.clone(); model.num_factors()],
            backward: vec![m; model.num_factors()],
        }
    }

    /// Current message on a directed edge.
    pub fn get(&self, e: EdgeKey) -> &GaussMessage {
        match e.dir {
            Direction::Forward => &self.forward[e.factor.0],
            Direction::Backward => &self.backward[e.factor.0],
        }
    }

    /// Replace the message on a directed edge.
    pub fn set(&mut self, e: EdgeKey, msg: GaussMessage) {
        match e.dir {
            Direction::Forward => self.forward[e.factor.0] = msg,
            Direction::Backward => self.backward[e.factor.0] = msg,
        }
    }
}

/// A lowered update: either a workload for the engine, or (for a
/// product of zero factors) the base message itself — nothing to run.
pub enum BuiltRequest {
    /// Nothing to execute: the base message is the result.
    Trivial(GaussMessage),
    /// A lowered model for the engine to run.
    Run(WorkloadRequest),
}

/// Per-round linearizations of the model's nonlinear factors, computed
/// by the solver at the current beliefs and consumed by the chain
/// builders below. Models without nonlinear factors use
/// [`RelinContext::empty`] (nothing to look up).
#[derive(Clone, Debug)]
pub struct RelinContext {
    /// Linearized unary factors, keyed by factor id.
    pub unary: HashMap<usize, Linearization>,
    /// Linearized pairwise factors, keyed by factor id.
    pub pairwise: HashMap<usize, PairRelin>,
    /// Variance of the vague base the (possibly rank-deficient)
    /// nonlinear pairwise likelihood is grafted onto.
    pub base_var: f64,
}

impl RelinContext {
    /// No linearizations (linear models).
    pub fn empty() -> Self {
        RelinContext { unary: HashMap::new(), pairwise: HashMap::new(), base_var: 10.0 }
    }

    /// Linearize every nonlinear factor of `model` at the given beliefs
    /// (one per variable — the solver passes its current beliefs, or
    /// the priors before the first round).
    pub fn relinearize(
        model: &GbpModel,
        beliefs: &[GaussMessage],
        linearizer: &dyn Linearizer,
        base_var: f64,
    ) -> Result<Self> {
        if beliefs.len() != model.num_vars() {
            bail!(
                "need one linearization belief per variable ({} != {})",
                beliefs.len(),
                model.num_vars()
            );
        }
        let mut ctx = RelinContext { base_var, ..RelinContext::empty() };
        for (i, f) in model.factors().iter().enumerate() {
            match f {
                Factor::NonlinearUnary { var, f } => {
                    let lin = linearizer
                        .linearize(f, &beliefs[var.0])
                        .with_context(|| format!("relinearizing unary factor {i}"))?;
                    ctx.unary.insert(i, lin);
                }
                Factor::NonlinearPairwise { from, to, f } => {
                    let pr = f
                        .linearize_with(linearizer, &beliefs[from.0], &beliefs[to.0])
                        .with_context(|| format!("relinearizing pairwise factor {i}"))?;
                    ctx.pairwise.insert(i, pr);
                }
                Factor::Unary { .. } | Factor::Pairwise { .. } => {}
            }
        }
        Ok(ctx)
    }
}

/// Incremental builder for the per-update chain graph. Exploits the
/// [`Schedule::forward_sweep`] invariant that edge `i` carries virtual
/// message id `i`, so input bindings are recorded as edges are created.
struct Chain {
    g: FactorGraph,
    inputs: HashMap<MsgId, GaussMessage>,
    /// Identity state shared by all fusion nodes.
    eye: Option<crate::gmp::graph::StateId>,
    cur: Option<EdgeId>,
    n: usize,
}

impl Chain {
    fn new(n: usize) -> Self {
        Chain { g: FactorGraph::new(), inputs: HashMap::new(), eye: None, cur: None, n }
    }

    fn input(&mut self, msg: &GaussMessage, label: String) -> EdgeId {
        let e = self.g.add_input_edge(self.n, label);
        self.inputs.insert(MsgId(e.0), msg.clone());
        e
    }

    /// Fuse `msg` into the running product (CN with identity state), or
    /// start the product if it is the first proper element.
    fn fuse(&mut self, msg: &GaussMessage, label: String) {
        let input = self.input(msg, label.clone());
        match self.cur {
            None => self.cur = Some(input),
            Some(prev) => {
                let eye = match self.eye {
                    Some(e) => e,
                    None => {
                        let e = self.g.add_state(CMatrix::identity(self.n));
                        self.eye = Some(e);
                        e
                    }
                };
                let out = self.g.add_edge(self.n, format!("fused_{label}"));
                self.g.add_node(
                    NodeKind::CompoundObservation { a: eye },
                    vec![prev, input],
                    out,
                    format!("fuse_{label}"),
                );
                self.cur = Some(out);
            }
        }
    }

    /// Condition the running product on an observation through `c`.
    fn condition(&mut self, c: &CMatrix, obs: &GaussMessage, label: String) -> Result<()> {
        let prev = self.cur.ok_or_else(|| {
            anyhow!("cannot condition an empty product (no proper base message)")
        })?;
        let input = self.input(obs, label.clone());
        let sid = self.g.add_state(c.clone());
        let out = self.g.add_edge(self.n, format!("cond_{label}"));
        self.g.add_node(
            NodeKind::CompoundObservation { a: sid },
            vec![prev, input],
            out,
            format!("cond_{label}"),
        );
        self.cur = Some(out);
        Ok(())
    }

    /// Multiply the running product by `a`.
    fn multiply(&mut self, a: &CMatrix, label: &str) -> Result<()> {
        let prev = self.cur.ok_or_else(|| anyhow!("multiply on empty product"))?;
        let sid = self.g.add_state(a.clone());
        let out = self.g.add_edge(self.n, format!("mul_{label}"));
        self.g.add_node(NodeKind::Multiply { a: sid }, vec![prev], out, format!("mul_{label}"));
        self.cur = Some(out);
        Ok(())
    }

    /// Add an independent Gaussian (widening by process noise).
    fn add(&mut self, noise: &GaussMessage, label: &str) -> Result<()> {
        let prev = self.cur.ok_or_else(|| anyhow!("add on empty product"))?;
        let input = self.input(noise, format!("noise_{label}"));
        let out = self.g.add_edge(self.n, format!("add_{label}"));
        self.g.add_node(NodeKind::Add, vec![prev, input], out, format!("add_{label}"));
        self.cur = Some(out);
        Ok(())
    }

    /// Condition an explicit `base` message on the **running product**
    /// as the observation, through `c` — the graft that turns a
    /// (possibly rank-deficient) linearized likelihood into a proper
    /// moment-form message: components `c` observes tighten around the
    /// likelihood, the rest stay at the vague base.
    fn condition_base(&mut self, base: &GaussMessage, c: &CMatrix, label: String) -> Result<()> {
        let y = self
            .cur
            .ok_or_else(|| anyhow!("cannot graft an empty product onto a base"))?;
        let base_edge = self.input(base, format!("base_{label}"));
        let sid = self.g.add_state(c.clone());
        let out = self.g.add_edge(self.n, format!("graft_{label}"));
        self.g.add_node(
            NodeKind::CompoundObservation { a: sid },
            vec![base_edge, y],
            out,
            format!("graft_{label}"),
        );
        self.cur = Some(out);
        Ok(())
    }

    fn finish(mut self) -> BuiltRequest {
        match self.cur {
            Some(out) if !self.g.nodes.is_empty() => {
                self.g.mark_output(out);
                let schedule = Schedule::forward_sweep(&self.g);
                BuiltRequest::Run(WorkloadRequest {
                    graph: self.g,
                    schedule,
                    inputs: self.inputs,
                    opts: Default::default(),
                    precision: None,
                })
            }
            Some(out) => {
                // zero nodes: the product is a single bound message
                let msg = self.inputs[&MsgId(out.0)].clone();
                BuiltRequest::Trivial(msg)
            }
            None => unreachable!("finish() called on an empty chain"),
        }
    }
}

/// Build the cavity product of `var` excluding `exclude` (all of it for
/// beliefs): prior, then other pairwise messages in factor order —
/// fused with identity-state compound nodes — then unary conditioning
/// (linear factors directly, nonlinear ones through their current
/// [`RelinContext`] linearization) in factor order.
fn cavity_chain(
    model: &GbpModel,
    state: &MessageState,
    relin: &RelinContext,
    var: VarId,
    exclude: Option<FactorId>,
) -> Result<Chain> {
    let mut chain = Chain::new(model.n());
    if let Some(prior) = &model.variable(var).prior {
        chain.fuse(prior, "prior".into());
    }
    for f in model.pairwise_at(var) {
        if Some(*f) == exclude {
            continue;
        }
        // the message flowing INTO `var` from factor f
        let dir = match model.factor(*f) {
            Factor::Pairwise { to, .. } | Factor::NonlinearPairwise { to, .. }
                if *to == var =>
            {
                Direction::Forward
            }
            _ => Direction::Backward,
        };
        chain.fuse(state.get(EdgeKey { factor: *f, dir }), format!("p{}", f.0));
    }
    if chain.cur.is_none() {
        bail!(
            "improper cavity at '{}': no prior and no other pairwise message",
            model.variable(var).label
        );
    }
    for f in model.unary_at(var) {
        match model.factor(*f) {
            Factor::Unary { c, obs, .. } => {
                chain.condition(c, obs, format!("u{}", f.0))?;
            }
            Factor::NonlinearUnary { .. } => {
                let lin = relin.unary.get(&f.0).ok_or_else(|| {
                    anyhow!("nonlinear unary factor {} has no linearization this round", f.0)
                })?;
                chain.condition(&lin.a, &lin.obs, format!("u{}", f.0))?;
            }
            _ => {}
        }
    }
    Ok(chain)
}

/// Lower one directed-edge update to a workload: cavity at the source
/// variable, then the factor's transform towards the target.
///
/// Linear pairwise factors push the cavity through the (invertible)
/// transform. Nonlinear ones use the round's linearization
/// `z_eff ≈ A_src x_src + A_tgt x_tgt + v`: the cavity at the source is
/// mapped to the pseudo-observation residual `N(z_eff − A_src·m,
/// R + A_src V A_srcᴴ)` (multiply by `−A_src`, add the observation),
/// which then conditions a vague base through `A_tgt` — a proper
/// moment-form stand-in for the generally rank-deficient likelihood.
pub fn edge_request(
    model: &GbpModel,
    state: &MessageState,
    relin: &RelinContext,
    edge: EdgeKey,
) -> Result<BuiltRequest> {
    match model.factor(edge.factor) {
        Factor::Pairwise { a, a_inv, noise, .. } => {
            let mut chain =
                cavity_chain(model, state, relin, edge.source(model), Some(edge.factor))?;
            match edge.dir {
                Direction::Forward => {
                    // x_to = A x_from + w:  multiply, then widen by N(b, Q)
                    chain.multiply(a, "fwd")?;
                    chain.add(noise, "fwd")?;
                }
                Direction::Backward => {
                    // x_from = A^{-1}(x_to - w): widen by N(-b, Q), then multiply
                    let neg_mean: Vec<c64> = noise.mean.iter().map(|z| -*z).collect();
                    let neg = GaussMessage::new(neg_mean, noise.cov.clone());
                    chain.add(&neg, "bwd")?;
                    chain.multiply(a_inv, "bwd")?;
                }
            }
            Ok(chain.finish())
        }
        Factor::NonlinearPairwise { .. } => {
            let pr = relin.pairwise.get(&edge.factor.0).ok_or_else(|| {
                anyhow!(
                    "nonlinear pairwise factor {} has no linearization this round",
                    edge.factor.0
                )
            })?;
            let (a_src, a_tgt, label) = match edge.dir {
                Direction::Forward => (&pr.a_from, &pr.a_to, "fwd"),
                Direction::Backward => (&pr.a_to, &pr.a_from, "bwd"),
            };
            let mut chain =
                cavity_chain(model, state, relin, edge.source(model), Some(edge.factor))?;
            chain.multiply(&a_src.neg(), label)?;
            chain.add(&pr.obs, label)?;
            chain.condition_base(
                &GaussMessage::isotropic(model.n(), relin.base_var),
                a_tgt,
                label.to_string(),
            )?;
            Ok(chain.finish())
        }
        _ => bail!("edge request on a non-pairwise factor {}", edge.factor.0),
    }
}

/// Lower one variable-belief product to a workload.
pub fn belief_request(
    model: &GbpModel,
    state: &MessageState,
    relin: &RelinContext,
    var: VarId,
) -> Result<BuiltRequest> {
    Ok(cavity_chain(model, state, relin, var, None)?.finish())
}

/// Anything that can execute a batch of lowered GBP updates. The two
/// implementations are a single [`Session`] (any engine, sequential)
/// and a [`FgpFarm`] (one round sharded across simulated devices).
pub trait RoundExecutor {
    /// Human-readable backend tag for reports.
    fn tag(&self) -> String;

    /// Execute each request and return its single output message, in
    /// request order.
    fn run_batch(&mut self, reqs: &[WorkloadRequest]) -> Result<Vec<GaussMessage>>;
}

impl RoundExecutor for Session {
    fn tag(&self) -> String {
        format!("session:{}", self.engine_kind())
    }

    fn run_batch(&mut self, reqs: &[WorkloadRequest]) -> Result<Vec<GaussMessage>> {
        reqs.iter()
            .map(|r| {
                let d = self.dispatch(&r.graph, &r.schedule, &r.inputs, &r.opts)?;
                Ok(d.exec.output()?.clone())
            })
            .collect()
    }
}

/// Shards a batch across an [`FgpFarm`]: all requests are submitted
/// asynchronously (the farm's routing policy spreads them over
/// devices), then collected in order.
pub struct FarmExecutor<'f> {
    /// The farm rounds are sharded over.
    pub farm: &'f FgpFarm,
}

impl RoundExecutor for FarmExecutor<'_> {
    fn tag(&self) -> String {
        format!("farm:{}dev", self.farm.size())
    }

    fn run_batch(&mut self, reqs: &[WorkloadRequest]) -> Result<Vec<GaussMessage>> {
        let pending: Vec<_> =
            reqs.iter().map(|r| self.farm.submit_workload(r.clone())).collect();
        pending
            .into_iter()
            .map(|(rx, idx)| {
                let exec = rx
                    .recv()
                    .map_err(|_| anyhow!("farm device {idx} died mid-round"))??;
                Ok(exec.output()?.clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::nodes;
    use crate::testutil::Rng;

    fn proper(rng: &mut Rng, n: usize) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(-0.5, 0.5), rng.range(-0.5, 0.5))).collect(),
            CMatrix::random_psd(rng, n, 1.0).scale(0.2),
        )
    }

    /// two variables, one pairwise link, priors on both
    fn two_var_model(rng: &mut Rng, n: usize) -> (GbpModel, GaussMessage, GaussMessage) {
        let mut m = GbpModel::new(n);
        let pa = proper(rng, n);
        let pb = proper(rng, n);
        let a = m.add_variable(Some(pa.clone()), "a").unwrap();
        let b = m.add_variable(Some(pb.clone()), "b").unwrap();
        m.add_pairwise(a, b, CMatrix::identity(n), GaussMessage::isotropic(n, 0.05))
            .unwrap();
        (m, pa, pb)
    }

    #[test]
    fn forward_edge_update_is_cavity_plus_noise() {
        // deg-1 source: cavity = prior; forward msg = A·prior + N(0, Q)
        let mut rng = Rng::new(1);
        let n = 4;
        let (model, pa, _) = two_var_model(&mut rng, n);
        let state = MessageState::vague(&model, 10.0);
        let edge = EdgeKey { factor: FactorId(0), dir: Direction::Forward };
        let req = match edge_request(&model, &state, &RelinContext::empty(), edge).unwrap() {
            BuiltRequest::Run(r) => r,
            BuiltRequest::Trivial(_) => panic!("transform always has nodes"),
        };
        let out = Session::golden()
            .dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts)
            .unwrap()
            .exec
            .output()
            .unwrap()
            .clone();
        let want = nodes::add(
            &nodes::multiply(&pa, &CMatrix::identity(n)),
            &GaussMessage::isotropic(n, 0.05),
        );
        assert!(out.dist(&want) < 1e-9, "dist {}", out.dist(&want));
    }

    #[test]
    fn belief_fuses_prior_and_message() {
        let mut rng = Rng::new(2);
        let n = 4;
        let (model, _, pb) = two_var_model(&mut rng, n);
        let mut state = MessageState::vague(&model, 10.0);
        let incoming = proper(&mut rng, n);
        state.set(EdgeKey { factor: FactorId(0), dir: Direction::Forward }, incoming.clone());
        let req = match belief_request(&model, &state, &RelinContext::empty(), VarId(1)).unwrap() {
            BuiltRequest::Run(r) => r,
            BuiltRequest::Trivial(_) => panic!("two-element product has a node"),
        };
        let out = Session::golden()
            .dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts)
            .unwrap()
            .exec
            .output()
            .unwrap()
            .clone();
        // identity-state CN fusion == moment-form Gaussian product
        let want = nodes::equality(&pb, &incoming).unwrap();
        assert!(out.dist(&want) < 1e-7, "dist {}", out.dist(&want));
    }

    #[test]
    fn prior_only_belief_is_trivial() {
        let n = 4;
        let mut m = GbpModel::new(n);
        let prior = GaussMessage::isotropic(n, 0.7);
        let v = m.add_variable(Some(prior.clone()), "lone").unwrap();
        let state = MessageState::vague(&m, 10.0);
        match belief_request(&m, &state, &RelinContext::empty(), v).unwrap() {
            BuiltRequest::Trivial(msg) => assert!(msg.dist(&prior) == 0.0),
            BuiltRequest::Run(_) => panic!("no factors: nothing to run"),
        }
    }

    #[test]
    fn edge_requests_fit_the_device() {
        // a degree-4 cavity must still compile for the n=4 device
        let mut rng = Rng::new(3);
        let n = 4;
        let mut m = GbpModel::new(n);
        let hub = m.add_variable(Some(proper(&mut rng, n)), "hub").unwrap();
        let mut spokes = Vec::new();
        for i in 0..4 {
            let s = m.add_variable(Some(proper(&mut rng, n)), format!("s{i}")).unwrap();
            m.add_pairwise(hub, s, CMatrix::identity(n), GaussMessage::isotropic(n, 0.05))
                .unwrap();
            spokes.push(s);
        }
        let mut y = vec![c64::ZERO; n];
        y[0] = c64::new(0.2, 0.0);
        let mut c = CMatrix::zeros(n, n);
        c[(0, 0)] = c64::ONE;
        m.add_unary(hub, c, GaussMessage::new(y, CMatrix::scaled_identity(n, 0.1)))
            .unwrap();
        let state = MessageState::vague(&m, 5.0);
        let edge = EdgeKey { factor: FactorId(0), dir: Direction::Forward };
        let BuiltRequest::Run(req) = edge_request(&m, &state, &RelinContext::empty(), edge).unwrap() else {
            panic!("expected a runnable request");
        };
        // cavity: prior + 3 other pairwise + 1 unary, then mul + add
        assert_eq!(req.graph.nodes.len(), 3 + 1 + 2);
        let mut sim = Session::fgp_sim(crate::fgp::FgpConfig::default());
        let d = sim.dispatch(&req.graph, &req.schedule, &req.inputs, &req.opts).unwrap();
        assert!(d.exec.stats.cycles > 0);
    }
}
