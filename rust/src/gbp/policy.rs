//! Iteration policies, damped updates, and the convergence monitor.
//!
//! Loopy GBP has no schedule derivable from the graph (that is the
//! point); *how* messages are revisited is a pluggable policy:
//!
//! * [`IterationPolicy::Synchronous`] — every directed edge updates each
//!   round from the previous round's messages (Jacobi style), optionally
//!   damped. Deterministic, embarrassingly parallel, the mode the device
//!   farm shards.
//! * [`IterationPolicy::Residual`] — residual-priority ("wildfire")
//!   scheduling: the directed edges whose inputs changed the most update
//!   first (Elidan et al. 2006; Ortiz et al. 2021 use the same rule for
//!   distributed GBP). Sequential-greedy, typically far fewer messages
//!   to convergence on irregular graphs.
//!
//! Damping interpolates in **information form**: `W ← (1-η)·W_new +
//! η·W_old` (and likewise for `Wm`). A convex combination of Hermitian
//! positive-definite matrices stays Hermitian positive-definite, so
//! damping can never manufacture an improper message — the property
//! test in `rust/tests/property_gbp.rs` pins this invariant.

use anyhow::{bail, Context, Result};

use crate::gmp::message::GaussMessage;

/// How the solver revisits directed edges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IterationPolicy {
    /// All directed edges every round, Jacobi style.
    Synchronous {
        /// Damping factor η ∈ [0, 1): 0 = undamped, larger = more of the
        /// old message retained (loopy grids typically want 0.2–0.5).
        eta_damping: f64,
    },
    /// Residual-priority scheduling: per iteration, the `batch` directed
    /// edges with the highest accumulated input residual update (and
    /// re-prime their downstream edges' priorities).
    Residual { batch: usize, eta_damping: f64 },
}

impl IterationPolicy {
    /// The policy's damping factor η.
    pub fn eta(&self) -> f64 {
        match self {
            IterationPolicy::Synchronous { eta_damping }
            | IterationPolicy::Residual { eta_damping, .. } => *eta_damping,
        }
    }
}

impl Default for IterationPolicy {
    fn default() -> Self {
        IterationPolicy::Synchronous { eta_damping: 0.0 }
    }
}

/// When to stop iterating.
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceCriteria {
    /// Belief-delta norm below which the solve has converged (max over
    /// variables of mean/covariance max-abs change per iteration).
    pub tol: f64,
    /// Iteration budget before the solve stops unconverged.
    pub max_iters: usize,
    /// Belief delta above which the solve is declared divergent (loopy
    /// GBP is not guaranteed to converge; catching the blow-up beats
    /// saturating to NaN). Non-finite deltas always count as divergence.
    pub divergence: f64,
}

impl Default for ConvergenceCriteria {
    fn default() -> Self {
        ConvergenceCriteria { tol: 1e-6, max_iters: 100, divergence: 1e3 }
    }
}

/// Why the solver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Belief delta fell below the tolerance (with policy quiescence).
    Converged,
    /// The iteration budget ran out before the tolerance was met.
    MaxIters,
    /// Belief deltas exceeded the divergence bound or became non-finite.
    Diverged,
}

/// Tracks belief deltas against the criteria.
#[derive(Clone, Debug)]
pub struct ConvergenceMonitor {
    /// The stopping criteria in force.
    pub criteria: ConvergenceCriteria,
    /// Belief delta observed per iteration.
    pub history: Vec<f64>,
}

impl ConvergenceMonitor {
    /// A monitor with no history yet.
    pub fn new(criteria: ConvergenceCriteria) -> Self {
        ConvergenceMonitor { criteria, history: Vec::new() }
    }

    /// Record one iteration's belief delta; `Some(reason)` if iteration
    /// must stop. `quiescent` additionally requires the policy's own
    /// work estimate (e.g. residual priorities) to be drained before
    /// declaring convergence.
    pub fn observe(&mut self, delta: f64, quiescent: bool) -> Option<StopReason> {
        self.history.push(delta);
        if !delta.is_finite() || delta > self.criteria.divergence {
            return Some(StopReason::Diverged);
        }
        if delta < self.criteria.tol && quiescent {
            return Some(StopReason::Converged);
        }
        if self.history.len() >= self.criteria.max_iters {
            return Some(StopReason::MaxIters);
        }
        None
    }

    /// Iterations observed so far.
    pub fn iterations(&self) -> usize {
        self.history.len()
    }

    /// The last observed belief delta (∞ before any iteration).
    pub fn final_delta(&self) -> f64 {
        self.history.last().copied().unwrap_or(f64::INFINITY)
    }
}

/// Damped message update in information form: η = 0 returns `new`
/// unchanged (bitwise — the undamped path must not round-trip through
/// the weight form, so the farm-sharding bitwise contract holds).
pub fn damp(old: &GaussMessage, new: &GaussMessage, eta: f64) -> Result<GaussMessage> {
    if !(0.0..1.0).contains(&eta) {
        bail!("eta_damping must be in [0, 1), got {eta}");
    }
    if eta == 0.0 {
        return Ok(new.clone());
    }
    let (wo, wom) = old
        .to_weight_form()
        .context("damping: old message covariance is singular")?;
    let (wn, wnm) = new
        .to_weight_form()
        .context("damping: new message covariance is singular")?;
    let w = wn.scale(1.0 - eta).add(&wo.scale(eta));
    let wm: Vec<_> = wnm
        .iter()
        .zip(&wom)
        .map(|(n, o)| *n * (1.0 - eta) + *o * eta)
        .collect();
    GaussMessage::from_weight_form(&w, &wm)
        .context("damping: interpolated weight matrix is singular")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::matrix::{c64, CMatrix};
    use crate::testutil::Rng;

    fn msg(rng: &mut Rng, n: usize) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect(),
            CMatrix::random_psd(rng, n, 0.5),
        )
    }

    #[test]
    fn zero_damping_is_bitwise_identity() {
        let mut rng = Rng::new(1);
        let old = msg(&mut rng, 4);
        let new = msg(&mut rng, 4);
        let d = damp(&old, &new, 0.0).unwrap();
        assert_eq!(d.mean, new.mean);
        assert!(d.cov.dist(&new.cov) == 0.0);
    }

    #[test]
    fn full_history_damping_approaches_old() {
        let mut rng = Rng::new(2);
        let old = msg(&mut rng, 3);
        let new = msg(&mut rng, 3);
        let d = damp(&old, &new, 0.999).unwrap();
        assert!(d.dist(&old) < 0.1, "dist {}", d.dist(&old));
    }

    #[test]
    fn damping_rejects_bad_eta() {
        let mut rng = Rng::new(3);
        let m = msg(&mut rng, 2);
        assert!(damp(&m, &m, 1.0).is_err());
        assert!(damp(&m, &m, -0.1).is_err());
    }

    #[test]
    fn monitor_converges_only_when_quiescent() {
        let mut mon = ConvergenceMonitor::new(ConvergenceCriteria {
            tol: 1e-3,
            max_iters: 10,
            divergence: 100.0,
        });
        assert_eq!(mon.observe(1e-4, false), None);
        assert_eq!(mon.observe(1e-4, true), Some(StopReason::Converged));
        assert_eq!(mon.iterations(), 2);
    }

    #[test]
    fn monitor_detects_divergence_and_nan() {
        let crit = ConvergenceCriteria { tol: 1e-6, max_iters: 10, divergence: 50.0 };
        let mut mon = ConvergenceMonitor::new(crit);
        assert_eq!(mon.observe(51.0, true), Some(StopReason::Diverged));
        let mut mon = ConvergenceMonitor::new(crit);
        assert_eq!(mon.observe(f64::NAN, true), Some(StopReason::Diverged));
    }

    #[test]
    fn monitor_caps_iterations() {
        let crit = ConvergenceCriteria { tol: 1e-9, max_iters: 3, divergence: 1e6 };
        let mut mon = ConvergenceMonitor::new(crit);
        assert_eq!(mon.observe(1.0, true), None);
        assert_eq!(mon.observe(1.0, true), None);
        assert_eq!(mon.observe(1.0, true), Some(StopReason::MaxIters));
        assert_eq!(mon.final_delta(), 1.0);
    }
}
