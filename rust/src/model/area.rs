//! Analytic area model of the FGP at UMC180 (paper §V).
//!
//! The paper reports: total 3.11 mm², of which 30% memories, 60%
//! systolic array, 10% datapath + control, at n = 4 and 64 kbit of
//! memory. We reconstruct those numbers from first principles:
//!
//! * UMC180's standard-cell density is ~**100 kGE/mm²** (2-input NAND
//!   equivalents), and single-port SRAM macros run ~**3.5 µm²/bit**
//!   including periphery at this node;
//! * a `PEmult` is a 16x16 multiplier (~2.5 kGE), a 32-bit
//!   adder/subtractor (~0.4 kGE), the StateReg planes (2 x 32-bit
//!   complex words, ~1.2 kGE of flops) and mode muxing (~0.5 kGE);
//! * a `PEborder` adds the sequential radix-2 divider (~1.5 kGE), a
//!   second multiplier and the abs/compare path;
//! * the FSM, Select/Mask/Transpose units and the command interface are
//!   charged per §III's description.
//!
//! These per-unit constants are *calibrated* (we cannot re-run UMC180
//! synthesis) such that the n = 4 / 64-kbit configuration lands on the
//! paper's total and split; the model then extrapolates to other n and
//! memory sizes for the scaling experiments (E8).

use crate::paper;

/// Per-unit area constants (mm², UMC180).
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// Area of one PEmult in mm².
    pub pemult_mm2: f64,
    /// Area of one PEborder in mm².
    pub peborder_mm2: f64,
    /// SRAM area per bit in mm² (macro incl. periphery).
    pub sram_mm2_per_bit: f64,
    /// Fixed datapath + control overhead (FSM, Select/Mask/Transpose,
    /// command interface) in mm².
    pub control_mm2: f64,
    /// Per-PE control distribution overhead in mm² (control signals of
    /// Fig. 5 scale with the array).
    pub control_per_pe_mm2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        // Calibrated so that n=4 / 64 kbit reproduces §V (see tests).
        AreaModel {
            pemult_mm2: 0.082,
            peborder_mm2: 0.126,
            sram_mm2_per_bit: 3.5e-6 * 4.0,
            control_mm2: 0.20,
            control_per_pe_mm2: 0.0055,
        }
    }
}

/// Area split of one configuration.
#[derive(Clone, Copy, Debug)]
pub struct AreaBreakdown {
    /// Memory macros (PM + message + state), mm².
    pub memories_mm2: f64,
    /// Systolic array, mm².
    pub array_mm2: f64,
    /// Datapath control + remaining logic, mm².
    pub control_mm2: f64,
}

impl AreaBreakdown {
    /// Total die area, mm².
    pub fn total(&self) -> f64 {
        self.memories_mm2 + self.array_mm2 + self.control_mm2
    }

    /// Fractions in the paper's reporting order (mem / array / control).
    pub fn fractions(&self) -> [f64; 3] {
        let t = self.total();
        [self.memories_mm2 / t, self.array_mm2 / t, self.control_mm2 / t]
    }
}

impl AreaModel {
    /// Area of an n x n FGP with `mem_kbit` of message+program memory.
    pub fn breakdown(&self, n: usize, mem_kbit: usize) -> AreaBreakdown {
        let pemults = (n * n) as f64;
        let peborders = n as f64;
        let array = pemults * self.pemult_mm2 + peborders * self.peborder_mm2;
        let memories = (mem_kbit * 1024) as f64 * self.sram_mm2_per_bit;
        let control = self.control_mm2 + (pemults + peborders) * self.control_per_pe_mm2;
        AreaBreakdown { memories_mm2: memories, array_mm2: array, control_mm2: control }
    }

    /// The paper's configuration (§V).
    pub fn paper_configuration(&self) -> AreaBreakdown {
        self.breakdown(paper::N, paper::MEMORY_KBIT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_matches_paper() {
        let b = AreaModel::default().paper_configuration();
        let rel = (b.total() - paper::FGP_AREA_MM2).abs() / paper::FGP_AREA_MM2;
        assert!(rel < 0.03, "total {:.3} mm² vs paper 3.11 (rel {rel:.3})", b.total());
    }

    #[test]
    fn split_matches_paper() {
        let b = AreaModel::default().paper_configuration();
        let f = b.fractions();
        for (got, want) in f.iter().zip(paper::FGP_AREA_SPLIT) {
            assert!(
                (got - want).abs() < 0.05,
                "fractions {f:?} vs paper {:?}",
                paper::FGP_AREA_SPLIT
            );
        }
    }

    #[test]
    fn array_area_scales_quadratically() {
        let m = AreaModel::default();
        let a4 = m.breakdown(4, 64).array_mm2;
        let a8 = m.breakdown(8, 64).array_mm2;
        let ratio = a8 / a4;
        assert!(ratio > 3.2 && ratio < 4.2, "ratio {ratio}");
    }

    #[test]
    fn memory_area_linear_in_bits() {
        let m = AreaModel::default();
        let b64 = m.breakdown(4, 64).memories_mm2;
        let b128 = m.breakdown(4, 128).memories_mm2;
        assert!((b128 / b64 - 2.0).abs() < 1e-9);
    }
}
