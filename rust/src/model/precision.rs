//! Quantization-conformance model: per-width error bounds, width-scaled
//! area/power, and the adaptive-precision policy (E-precision).
//!
//! The paper fixes the FGP word at Q5.10 (§III: 16-bit two's complement,
//! 5 integer + 10 fractional bits). This module answers the question a
//! deployment actually faces: *which* width does a given workload need?
//! Three pieces:
//!
//! * [`PrecisionModel::error_bound`] — an analytic per-width bound on
//!   the end-to-end error of a compound-observation chain vs the golden
//!   f64 engine. One CN update quantizes every intermediate to the
//!   format's resolution `2^-frac`; ill-conditioned section covariances
//!   amplify those rounding errors through the matrix inverse, so the
//!   bound is `C · chain_len · κ̂ · 2^-frac` with a calibrated headroom
//!   constant `C` and a cheap condition-number estimate `κ̂`
//!   ([`condition_estimate`]). The bench (`precision_ablation`) asserts
//!   measured error stays under this bound for every swept width — the
//!   bound is a *contract*, not a curve fit.
//! * [`PrecisionModel::breakdown`] / [`PrecisionModel::power_point`] —
//!   Table II rows at other word widths. Relative to the calibrated
//!   16-bit [`AreaModel`]: array multipliers scale quadratically with
//!   width, adders/flops/dividers and memory bits linearly, control not
//!   at all.
//! * [`PrecisionModel::pick_format`] — the adaptive-precision policy:
//!   the narrowest candidate width whose bound meets a target accuracy,
//!   i.e. the cheapest device that is still *provably* accurate enough.

use crate::fixed::QFormat;
use crate::gmp::matrix::CMatrix;
use crate::gmp::message::GaussMessage;
use crate::paper;

use super::area::{AreaBreakdown, AreaModel};
use super::power::PowerPoint;

/// Word width (bits) the [`AreaModel`] constants are calibrated at —
/// the paper's Q5.10 configuration.
const REFERENCE_WIDTH: f64 = 16.0;

/// Fraction of a PE's area in multipliers (quadratic in width); the
/// remainder (adders, state flops, muxing, the border divider) scales
/// linearly. From the §V gate-count split: ~2.5 kGE multiplier out of
/// ~4.6 kGE per PEmult.
const MULT_FRACTION: f64 = 0.55;

/// Cheap condition-number estimate for a compound-observation chain:
/// the worst ratio of largest to smallest covariance diagonal magnitude
/// across the prior and every section, clamped to at least 1. The exact
/// condition number of each inverted sum is unavailable without an
/// eigensolve; the diagonal ratio is a standard sufficient proxy for
/// the *bound* (which carries calibrated headroom on top).
pub fn condition_estimate(prior: &GaussMessage, sections: &[(GaussMessage, CMatrix)]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = 0.0f64;
    let mut scan = |m: &GaussMessage| {
        let n = m.dim();
        for i in 0..n {
            let d = m.cov[(i, i)].abs();
            lo = lo.min(d);
            hi = hi.max(d);
        }
    };
    scan(prior);
    for (msg, _) in sections {
        scan(msg);
    }
    if lo <= 0.0 || !lo.is_finite() || hi <= 0.0 {
        return 1.0;
    }
    (hi / lo).max(1.0)
}

/// Analytic precision/cost model over Q-format word widths.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionModel {
    /// Base per-unit area constants (calibrated at 16-bit words).
    pub area: AreaModel,
    /// Calibrated headroom constant of the error bound. Large enough
    /// that every measured workload sits under the bound, small enough
    /// that the bound still separates adjacent widths by ~2x per
    /// fractional bit.
    pub error_constant: f64,
}

impl Default for PrecisionModel {
    fn default() -> Self {
        PrecisionModel { area: AreaModel::default(), error_constant: 8.0 }
    }
}

impl PrecisionModel {
    /// Upper bound on the max-abs error of a `chain_len`-section
    /// compound-observation chain executed at `fmt`, relative to the
    /// golden f64 engine, for a workload with condition estimate
    /// `cond` (see [`condition_estimate`]).
    pub fn error_bound(&self, fmt: QFormat, chain_len: usize, cond: f64) -> f64 {
        self.error_constant * (chain_len.max(1) as f64) * cond.max(1.0) * fmt.resolution()
    }

    /// [`AreaBreakdown`] of an n x n FGP at word width `fmt`:
    /// multipliers quadratic in width, everything else in the array and
    /// the memories linear, control fixed.
    pub fn breakdown(&self, n: usize, mem_kbit: usize, fmt: QFormat) -> AreaBreakdown {
        let base = self.area.breakdown(n, mem_kbit);
        let r = fmt.width() as f64 / REFERENCE_WIDTH;
        let array_scale = MULT_FRACTION * r * r + (1.0 - MULT_FRACTION) * r;
        AreaBreakdown {
            memories_mm2: base.memories_mm2 * r,
            array_mm2: base.array_mm2 * array_scale,
            control_mm2: base.control_mm2,
        }
    }

    /// Table II power row at word width `fmt` (the paper's n and
    /// memory size): area-based dynamic power at the scaled die size.
    pub fn power_point(&self, fmt: QFormat, cn_cycles: u64) -> PowerPoint {
        let area = self.breakdown(paper::N, paper::MEMORY_KBIT, fmt).total();
        PowerPoint::fgp(cn_cycles, area)
    }

    /// The adaptive-precision policy: the narrowest candidate whose
    /// [`error_bound`](Self::error_bound) meets `target` for this
    /// workload shape, or `None` when no candidate qualifies (run f64).
    pub fn pick_format(
        &self,
        target: f64,
        chain_len: usize,
        cond: f64,
        candidates: &[QFormat],
    ) -> Option<QFormat> {
        let mut sorted: Vec<QFormat> = candidates.to_vec();
        sorted.sort_by_key(|f| f.width());
        sorted.into_iter().find(|f| self.error_bound(*f, chain_len, cond) <= target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> Vec<QFormat> {
        [(5u32, 10u32), (5, 12), (5, 14), (5, 18), (5, 22), (5, 26)]
            .iter()
            .map(|&(i, f)| QFormat::new(i, f))
            .collect()
    }

    #[test]
    fn error_bound_halves_per_fractional_bit() {
        let m = PrecisionModel::default();
        let a = m.error_bound(QFormat::new(5, 10), 16, 4.0);
        let b = m.error_bound(QFormat::new(5, 11), 16, 4.0);
        assert!((a / b - 2.0).abs() < 1e-12, "{a} vs {b}");
    }

    #[test]
    fn error_bound_grows_with_chain_and_conditioning() {
        let m = PrecisionModel::default();
        let f = QFormat::q5_10();
        assert!(m.error_bound(f, 32, 1.0) > m.error_bound(f, 16, 1.0));
        assert!(m.error_bound(f, 16, 10.0) > m.error_bound(f, 16, 1.0));
        // degenerate inputs clamp instead of vanishing
        assert_eq!(m.error_bound(f, 0, 0.0), m.error_bound(f, 1, 1.0));
    }

    #[test]
    fn condition_estimate_reads_covariance_spread() {
        let prior = GaussMessage::isotropic(2, 1.0);
        let tight = vec![(GaussMessage::isotropic(2, 1.0), CMatrix::identity(2))];
        assert!((condition_estimate(&prior, &tight) - 1.0).abs() < 1e-12);
        let wide = vec![(GaussMessage::isotropic(2, 100.0), CMatrix::identity(2))];
        assert!((condition_estimate(&prior, &wide) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn wider_words_cost_more_area_and_power() {
        let m = PrecisionModel::default();
        let narrow = m.breakdown(paper::N, paper::MEMORY_KBIT, QFormat::q5_10());
        let wide = m.breakdown(paper::N, paper::MEMORY_KBIT, QFormat::new(5, 26));
        assert!(wide.total() > narrow.total());
        assert!(wide.array_mm2 / narrow.array_mm2 > 2.0, "multipliers scale quadratically");
        assert!(
            m.power_point(QFormat::new(5, 26), paper::FGP_CN_CYCLES).power_w
                > m.power_point(QFormat::q5_10(), paper::FGP_CN_CYCLES).power_w
        );
    }

    #[test]
    fn reference_width_reproduces_the_calibrated_model() {
        let m = PrecisionModel::default();
        let scaled = m.breakdown(paper::N, paper::MEMORY_KBIT, QFormat::q5_10());
        let base = m.area.breakdown(paper::N, paper::MEMORY_KBIT);
        assert!((scaled.total() - base.total()).abs() < 1e-12, "16-bit is the identity");
    }

    #[test]
    fn policy_picks_the_narrowest_sufficient_width() {
        let m = PrecisionModel::default();
        let widths = sweep();
        // a loose target admits the narrowest sweep entry
        let loose = m.error_bound(QFormat::q5_10(), 16, 4.0);
        assert_eq!(m.pick_format(loose, 16, 4.0, &widths), Some(QFormat::q5_10()));
        // a tight target forces a wider word
        let tight = m.error_bound(QFormat::new(5, 22), 16, 4.0);
        let picked = m.pick_format(tight, 16, 4.0, &widths).unwrap();
        assert_eq!(picked, QFormat::new(5, 22), "narrowest that still meets the target");
        // an impossible target refuses fixed point entirely
        assert_eq!(m.pick_format(1e-12, 16, 4.0, &widths), None);
    }
}
