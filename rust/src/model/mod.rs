//! S7 — Area and technology models (paper §V, Table II footnote 3).
//!
//! * [`area`]: an analytic gate/SRAM area model of the FGP at UMC180,
//!   calibrated to reproduce the paper's 3.11 mm² with the reported
//!   30% memories / 60% systolic array / 10% control split.
//! * [`scaling`]: the paper's technology scaling `t_pd ~ 1/s` — Table
//!   II's "normalized max. throughput" scales both processors to a
//!   common node before dividing clock by cycles-per-update.
//! * [`precision`]: per-width error bounds, width-scaled area/power
//!   rows, and the adaptive-precision policy behind the fixed-point
//!   production path (`BENCH_precision.json`).

pub mod area;
pub mod power;
pub mod precision;
pub mod scaling;

pub use area::{AreaBreakdown, AreaModel};
pub use power::PowerPoint;
pub use precision::{condition_estimate, PrecisionModel};
pub use scaling::{normalized_throughput, scale_frequency, ProcessorPoint};
