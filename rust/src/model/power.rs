//! Power and energy-efficiency model (extension of §V / Table II).
//!
//! The paper compares throughput only, but its references carry the power
//! data for the energy story: ref [10] reports the C66x core at **0.8 W @
//! 1.25 GHz** in 40 nm. For the FGP we estimate dynamic power from the
//! area model with standard UMC180 power density for datapath-dominated
//! logic (~0.15 mW/MHz/mm² at moderate switching activity, typical of
//! published 180 nm DSP datapaths), plus SRAM access energy.
//!
//! The headline derived metric is **energy per compound-node update**
//! (nJ/CN) at each processor's native operating point, and scaled to a
//! common node with constant-field scaling (energy/op ∼ s·V², here the
//! paper's simple `t_pd ∼ 1/s` companion: E ∼ 1/s² per node shrink —
//! documented as modeled, the paper publishes no FGP power number).

use crate::paper;

/// A processor power/energy operating point.
#[derive(Clone, Copy, Debug)]
pub struct PowerPoint {
    /// Processor name (reports).
    pub name: &'static str,
    /// Clock frequency in MHz at the native node.
    pub freq_mhz: f64,
    /// Technology node in nm.
    pub node_nm: f64,
    /// Core power at the native node and frequency, in watts.
    pub power_w: f64,
    /// Cycles per compound-node update.
    pub cn_cycles: u64,
}

impl PowerPoint {
    /// The C66x anchor from ref [10]: 0.8 W @ 1.25 GHz, 40 nm.
    pub fn c66x(cn_cycles: u64) -> Self {
        PowerPoint {
            name: "TI C66x",
            freq_mhz: paper::DSP_FREQ_MHZ,
            node_nm: paper::DSP_NODE_NM,
            power_w: 0.8,
            cn_cycles,
        }
    }

    /// The FGP estimate: area-based dynamic power at UMC180.
    pub fn fgp(cn_cycles: u64, area_mm2: f64) -> Self {
        // 0.15 mW/MHz/mm2 on the active (non-SRAM) area + SRAM overhead,
        // folded into one effective density over the whole die.
        let mw_per_mhz_mm2 = 0.15;
        let power_w = mw_per_mhz_mm2 * paper::FGP_FREQ_MHZ * area_mm2 / 1000.0;
        PowerPoint {
            name: "FGP (this work)",
            freq_mhz: paper::FGP_FREQ_MHZ,
            node_nm: paper::FGP_NODE_NM,
            power_w,
            cn_cycles,
        }
    }

    /// Energy per compound-node update at the native point, in nanojoules.
    pub fn energy_per_cn_nj(&self) -> f64 {
        let time_s = self.cn_cycles as f64 / (self.freq_mhz * 1e6);
        self.power_w * time_s * 1e9
    }

    /// Energy per CN scaled to `node_nm` (constant-field: E ∼ s²).
    pub fn energy_per_cn_nj_at(&self, node_nm: f64) -> f64 {
        let s = self.node_nm / node_nm; // > 1 when shrinking
        self.energy_per_cn_nj() / (s * s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::area::AreaModel;

    #[test]
    fn c66x_energy_matches_anchor_arithmetic() {
        let p = PowerPoint::c66x(paper::DSP_CN_CYCLES);
        // 0.8 W * (1076 / 1.25e9) s = 688.6 nJ
        let e = p.energy_per_cn_nj();
        assert!((e - 688.6).abs() < 1.0, "{e}");
    }

    #[test]
    fn fgp_energy_is_computed_from_area() {
        let area = AreaModel::default().paper_configuration().total();
        let p = PowerPoint::fgp(paper::FGP_CN_CYCLES, area);
        // ~0.0593 W at 130 MHz and ~3.04 mm²; 260 cycles -> ~119 nJ
        assert!(p.power_w > 0.04 && p.power_w < 0.08, "{}", p.power_w);
        let e = p.energy_per_cn_nj();
        assert!(e > 60.0 && e < 200.0, "{e}");
    }

    #[test]
    fn fgp_wins_energy_even_before_scaling() {
        let area = AreaModel::default().paper_configuration().total();
        let fgp = PowerPoint::fgp(paper::FGP_CN_CYCLES, area);
        let dsp = PowerPoint::c66x(paper::DSP_CN_CYCLES);
        // the 180 nm FGP already beats the 40 nm DSP on energy/CN
        assert!(fgp.energy_per_cn_nj() < dsp.energy_per_cn_nj());
    }

    #[test]
    fn scaling_reduces_energy_quadratically() {
        let p = PowerPoint::c66x(paper::DSP_CN_CYCLES);
        let native = p.energy_per_cn_nj_at(40.0);
        let shrunk = p.energy_per_cn_nj_at(20.0);
        assert!((native / shrunk - 4.0).abs() < 1e-9);
    }
}
