//! Technology scaling + normalized throughput (Table II, footnote 3).
//!
//! The paper compares a 180 nm FGP at 130 MHz against a 40 nm C66x at
//! 1.25 GHz by scaling to a common node with classic constant-field
//! scaling, `t_pd ∼ 1/s` (footnote 3): frequency scales linearly with
//! the ratio of feature sizes. Working the published numbers backwards,
//! Table II's "normalized max. throughput" row scales the FGP *up* to
//! the DSP's 40 nm node:
//!
//! ```text
//!   FGP : 130 MHz * (180/40) / 260 cycles  = 2.25e6 CN/s
//!   DSP : 1250 MHz            / 1076 cycles = 1.16e6 CN/s
//! ```
//!
//! [`normalized_throughput`] reproduces exactly that computation for any
//! pair of processor operating points.

/// A processor operating point.
#[derive(Clone, Copy, Debug)]
pub struct ProcessorPoint {
    /// Processor name (reports).
    pub name: &'static str,
    /// Clock frequency in MHz at the native node.
    pub freq_mhz: f64,
    /// Native technology node in nm.
    pub node_nm: f64,
    /// Cycles per compound-node message update.
    pub cn_cycles: u64,
}

impl ProcessorPoint {
    /// The paper's FGP row with a measured cycle count substituted in.
    pub fn fgp(cn_cycles: u64) -> Self {
        ProcessorPoint {
            name: "FGP (this work)",
            freq_mhz: crate::paper::FGP_FREQ_MHZ,
            node_nm: crate::paper::FGP_NODE_NM,
            cn_cycles,
        }
    }

    /// The paper's TI C66x row.
    pub fn c66x(cn_cycles: u64) -> Self {
        ProcessorPoint {
            name: "TI C66x",
            freq_mhz: crate::paper::DSP_FREQ_MHZ,
            node_nm: crate::paper::DSP_NODE_NM,
            cn_cycles,
        }
    }
}

/// Frequency after scaling from `from_nm` to `to_nm` (t_pd ∼ 1/s).
pub fn scale_frequency(freq_mhz: f64, from_nm: f64, to_nm: f64) -> f64 {
    freq_mhz * (from_nm / to_nm)
}

/// Compound-node updates per second, with the clock scaled to `node_nm`.
pub fn normalized_throughput(p: &ProcessorPoint, node_nm: f64) -> f64 {
    let f = scale_frequency(p.freq_mhz, p.node_nm, node_nm) * 1e6;
    f / p.cn_cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paper;
    use crate::testutil::assert_close;

    #[test]
    fn reproduces_table2_fgp_row() {
        let fgp = ProcessorPoint::fgp(paper::FGP_CN_CYCLES);
        let t = normalized_throughput(&fgp, paper::DSP_NODE_NM);
        assert_close(t, 2.25e6, 0.01);
    }

    #[test]
    fn reproduces_table2_dsp_row() {
        let dsp = ProcessorPoint::c66x(paper::DSP_CN_CYCLES);
        let t = normalized_throughput(&dsp, paper::DSP_NODE_NM);
        assert_close(t, 1.16e6, 0.01);
    }

    #[test]
    fn paper_speedup_is_about_2x() {
        let fgp = ProcessorPoint::fgp(paper::FGP_CN_CYCLES);
        let dsp = ProcessorPoint::c66x(paper::DSP_CN_CYCLES);
        let ratio = normalized_throughput(&fgp, 40.0) / normalized_throughput(&dsp, 40.0);
        assert!(ratio > 1.8 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn normalization_node_does_not_change_ratio() {
        let fgp = ProcessorPoint::fgp(paper::FGP_CN_CYCLES);
        let dsp = ProcessorPoint::c66x(paper::DSP_CN_CYCLES);
        let r40 = normalized_throughput(&fgp, 40.0) / normalized_throughput(&dsp, 40.0);
        let r180 = normalized_throughput(&fgp, 180.0) / normalized_throughput(&dsp, 180.0);
        assert_close(r40, r180, 1e-12);
    }

    #[test]
    fn scaling_is_linear_in_feature_size() {
        assert_close(scale_frequency(130.0, 180.0, 40.0), 585.0, 1e-12);
        assert_close(scale_frequency(585.0, 40.0, 180.0), 130.0, 1e-12);
    }
}
