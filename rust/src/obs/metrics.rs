//! The unified metrics registry: named counters, gauges and histograms
//! behind one snapshot type.
//!
//! Before this module each layer kept its own numbers — the serve
//! tier's [`Metrics`](crate::coordinator::Metrics) bundle, the session
//! program-cache hit/miss pair, coalescer batch stats, the profiler's
//! per-opcode cycle totals. [`MetricsRegistry`] gives them one
//! namespace (`layer.noun[.verb]`, e.g. `engine.cache_hit`,
//! `fgp.cycles.fad`) and one export path: [`RegistrySnapshot`], which
//! the extended `STATS` wire reply carries and the bench layer writes
//! to `BENCH_obs.json`.
//!
//! Registration is `RwLock`-guarded (a `BTreeMap` keeps snapshots in
//! deterministic name order), but *recording* is lock-free: `counter`
//! and `histogram` hand back `Arc`s to atomics that hot paths cache and
//! bump without ever touching the maps again.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::coordinator::Histogram;

/// One named counter/gauge sample in a [`RegistrySnapshot`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CounterSample {
    /// Metric name (`layer.noun[.verb]`).
    pub name: String,
    /// Current value.
    pub value: u64,
}

/// One named histogram summary in a [`RegistrySnapshot`] — the same
/// five numbers as [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot),
/// per named distribution.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    /// Metric name.
    pub name: String,
    /// Samples recorded.
    pub count: u64,
    /// Mean in nanoseconds.
    pub mean_ns: u64,
    /// p50 in nanoseconds (bucket midpoint).
    pub p50_ns: u64,
    /// p95 in nanoseconds (bucket midpoint).
    pub p95_ns: u64,
    /// p99 in nanoseconds (bucket midpoint).
    pub p99_ns: u64,
}

impl HistSummary {
    /// Summarize a live histogram under `name`.
    pub fn of(name: &str, h: &Histogram) -> Self {
        let ns = |d: Duration| d.as_nanos().min(u128::from(u64::MAX)) as u64;
        HistSummary {
            name: name.to_string(),
            count: h.count(),
            mean_ns: ns(h.mean()),
            p50_ns: ns(h.quantile(0.5)),
            p95_ns: ns(h.quantile(0.95)),
            p99_ns: ns(h.quantile(0.99)),
        }
    }
}

/// Point-in-time copy of a whole [`MetricsRegistry`] (or any ad-hoc
/// assembly of samples — the serve tier folds its legacy atomics in at
/// snapshot time). All three lists are kept sorted by name so snapshots
/// are deterministic, diffable and wire-stable.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RegistrySnapshot {
    /// Monotone counter samples, sorted by name.
    pub counters: Vec<CounterSample>,
    /// Gauge samples (last-write-wins level readings), sorted by name.
    pub gauges: Vec<CounterSample>,
    /// Histogram summaries, sorted by name.
    pub histograms: Vec<HistSummary>,
}

impl RegistrySnapshot {
    /// Empty snapshot.
    pub fn new() -> Self {
        Self::default()
    }

    /// No samples at all?
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Append a counter sample (call [`RegistrySnapshot::sort`] after a
    /// batch of pushes).
    pub fn push_counter(&mut self, name: &str, value: u64) {
        self.counters.push(CounterSample { name: name.to_string(), value });
    }

    /// Append a gauge sample.
    pub fn push_gauge(&mut self, name: &str, value: u64) {
        self.gauges.push(CounterSample { name: name.to_string(), value });
    }

    /// Append a histogram summary.
    pub fn push_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms.push(HistSummary::of(name, h));
    }

    /// Restore name order after out-of-order pushes.
    pub fn sort(&mut self) {
        self.counters.sort_by(|a, b| a.name.cmp(&b.name));
        self.gauges.sort_by(|a, b| a.name.cmp(&b.name));
        self.histograms.sort_by(|a, b| a.name.cmp(&b.name));
    }

    /// Look up a counter by name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|c| c.name == name).map(|c| c.value)
    }

    /// Look up a gauge by name.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|g| g.name == name).map(|g| g.value)
    }

    /// Look up a histogram summary by name.
    pub fn histogram(&self, name: &str) -> Option<&HistSummary> {
        self.histograms.iter().find(|h| h.name == name)
    }
}

/// Named counter/gauge/histogram table. Cheap to share (`Arc` the
/// owning [`Telemetry`](super::Telemetry)); cheap to record into
/// (atomics behind `Arc`s — hold the handle, skip the map).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    hists: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

/// Recover from a poisoned registry lock: the data is atomics, always
/// in a valid state, so the poison flag carries no information here.
fn read_or_recover<T: ?Sized>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

fn write_or_recover<T: ?Sized>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter named `name`, created at 0 on first sight. Cache the
    /// returned `Arc` on hot paths.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        if let Some(c) = read_or_recover(&self.counters).get(name) {
            return Arc::clone(c);
        }
        let mut map = write_or_recover(&self.counters);
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))))
    }

    /// Add `v` to counter `name`.
    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    /// The gauge named `name`, created at 0 on first sight. Gauges are
    /// level readings (last write wins) and live in their own namespace:
    /// `set("x", _)` never aliases `counter("x")`'s storage.
    pub fn gauge(&self, name: &str) -> Arc<AtomicU64> {
        if let Some(g) = read_or_recover(&self.gauges).get(name) {
            return Arc::clone(g);
        }
        let mut map = write_or_recover(&self.gauges);
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))))
    }

    /// Set gauge `name` to `v`.
    pub fn set(&self, name: &str, v: u64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    /// The histogram named `name`, created empty on first sight.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(h) = read_or_recover(&self.hists).get(name) {
            return Arc::clone(h);
        }
        let mut map = write_or_recover(&self.hists);
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())))
    }

    /// Record `ns` nanoseconds into histogram `name`.
    pub fn record_ns(&self, name: &str, ns: u64) {
        self.histogram(name).record(Duration::from_nanos(ns));
    }

    /// Fold another histogram into `name` — cross-device aggregation
    /// (each farm device keeps local histograms; the STATS path merges
    /// them here).
    pub fn merge_histogram(&self, name: &str, other: &Histogram) {
        self.histogram(name).merge(other);
    }

    /// Point-in-time snapshot, sorted by name (the `BTreeMap` order).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let mut snap = RegistrySnapshot::new();
        for (name, c) in read_or_recover(&self.counters).iter() {
            snap.counters.push(CounterSample { name: name.clone(), value: c.load(Ordering::Relaxed) });
        }
        for (name, g) in read_or_recover(&self.gauges).iter() {
            snap.gauges.push(CounterSample { name: name.clone(), value: g.load(Ordering::Relaxed) });
        }
        for (name, h) in read_or_recover(&self.hists).iter() {
            snap.histograms.push(HistSummary::of(name, h));
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_create_once_and_accumulate() {
        let r = MetricsRegistry::new();
        r.add("a.hits", 2);
        r.add("a.hits", 3);
        r.set("a.gauge", 7);
        let c = r.counter("a.hits");
        c.fetch_add(1, Ordering::Relaxed);
        let snap = r.snapshot();
        assert_eq!(snap.counter("a.hits"), Some(6));
        assert_eq!(snap.gauge("a.gauge"), Some(7));
        assert_eq!(snap.counter("missing"), None);
    }

    #[test]
    fn gauges_are_last_write_wins_and_do_not_alias_counters() {
        let r = MetricsRegistry::new();
        r.set("depth", 9);
        r.set("depth", 4);
        r.add("depth", 100); // a *counter* named "depth": separate storage
        let snap = r.snapshot();
        assert_eq!(snap.gauge("depth"), Some(4), "last write wins");
        assert_eq!(snap.counter("depth"), Some(100), "counter untouched by set()");
        assert_eq!(snap.gauge("missing"), None);
    }

    #[test]
    fn gauge_handles_are_shared_and_snapshots_sorted() {
        let r = MetricsRegistry::new();
        r.set("z.g", 1);
        r.set("a.g", 2);
        let g = r.gauge("z.g");
        g.store(5, Ordering::Relaxed);
        let snap = r.snapshot();
        assert_eq!(snap.gauges[0].name, "a.g");
        assert_eq!(snap.gauges[1].name, "z.g");
        assert_eq!(snap.gauge("z.g"), Some(5));
    }

    #[test]
    fn histograms_record_and_summarize() {
        let r = MetricsRegistry::new();
        for _ in 0..10 {
            r.record_ns("lat", 1000);
        }
        let snap = r.snapshot();
        let h = snap.histogram("lat").unwrap();
        assert_eq!(h.count, 10);
        assert!(h.p50_ns >= 512 && h.p50_ns <= 2048, "midpoint of the 1µs bucket, got {}", h.p50_ns);
        assert!(h.p50_ns <= h.p95_ns && h.p95_ns <= h.p99_ns);
    }

    #[test]
    fn snapshot_is_name_sorted_and_eq_comparable() {
        let r = MetricsRegistry::new();
        r.add("z.last", 1);
        r.add("a.first", 1);
        r.record_ns("m.mid", 5);
        let snap = r.snapshot();
        assert_eq!(snap.counters[0].name, "a.first");
        assert_eq!(snap.counters[1].name, "z.last");
        assert_eq!(snap, r.snapshot());
        assert!(!snap.is_empty());
        assert!(RegistrySnapshot::new().is_empty());
    }

    #[test]
    fn hist_summary_of_empty_is_all_zero() {
        let h = Histogram::new();
        let s = HistSummary::of("empty", &h);
        assert_eq!(s.name, "empty");
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_ns, 0);
        assert_eq!((s.p50_ns, s.p95_ns, s.p99_ns), (0, 0, 0));
    }

    #[test]
    fn hist_summary_of_single_sample() {
        let h = Histogram::new();
        h.record(Duration::from_micros(1)); // bucket [512, 1023]
        let s = HistSummary::of("one", &h);
        assert_eq!(s.count, 1);
        assert_eq!(s.mean_ns, 1000);
        let mid = 512 + (1023 - 512) / 2;
        assert_eq!((s.p50_ns, s.p95_ns, s.p99_ns), (mid, mid, mid));
    }

    #[test]
    fn merge_histogram_aggregates_across_sources() {
        let local = Histogram::new();
        for _ in 0..4 {
            local.record(Duration::from_micros(10));
        }
        let r = MetricsRegistry::new();
        r.record_ns("dev.lat", 10_000);
        r.merge_histogram("dev.lat", &local);
        assert_eq!(r.snapshot().histogram("dev.lat").unwrap().count, 5);
    }

    #[test]
    fn push_and_sort_keep_manual_snapshots_ordered() {
        let mut snap = RegistrySnapshot::new();
        snap.push_counter("b", 2);
        snap.push_counter("a", 1);
        snap.push_gauge("g2", 20);
        snap.push_gauge("g1", 10);
        let h = Histogram::new();
        h.record(Duration::from_nanos(100));
        snap.push_histogram("hist", &h);
        snap.sort();
        assert_eq!(snap.counters[0].name, "a");
        assert_eq!(snap.gauges[0].name, "g1");
        assert_eq!(snap.histogram("hist").unwrap().count, 1);
        assert!(!snap.is_empty());
        let mut only_gauge = RegistrySnapshot::new();
        only_gauge.push_gauge("g", 1);
        assert!(!only_gauge.is_empty(), "a lone gauge counts as data");
    }
}
