//! Exporters: Chrome trace-event JSON and a per-request flame summary.
//!
//! [`chrome_trace`] emits the Trace Event Format (the JSON that
//! `chrome://tracing` / Perfetto load): every [`SpanRecord`] becomes a
//! complete event (`ph: "X"`) with microsecond timestamps, one row
//! (`tid`) per recording layer, and the trace/span/parent ids in
//! `args` so the tree is recoverable in the UI. Device cycle spans are
//! recorded pre-rescaled onto the wall clock (cycles × 1/130 MHz — see
//! `FgpSimEngine`), so a compiled program's MMA/FAD phases render
//! inside the serving span that dispatched them.
//!
//! [`flame_summary`] is the terminal-sized version: one request's span
//! tree, indented, durations in microseconds — the "why was this chunk
//! slow" answer without leaving the shell.
//!
//! [`prometheus_text`] renders a [`RegistrySnapshot`] in the Prometheus
//! text exposition format (version 0.0.4): counters and gauges as typed
//! scalar families, histograms as `summary` families with `quantile`
//! labels plus `_sum`/`_count`. Durations stay in integer nanoseconds
//! (`_ns`-suffixed names) so the export is exact — no float division of
//! the bucket midpoints on the way out.
//!
//! All are hand-rolled JSON/text over `std::fmt` — the vendored set
//! has no serializer and the event shapes are fixed.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::metrics::RegistrySnapshot;
use super::span::SpanRecord;

/// Escape a string for a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Microseconds with nanosecond precision, as a JSON number.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Render spans as Chrome trace-event JSON (`{"traceEvents": [...]}`).
///
/// One `ph: "M"` thread-name metadata event per layer (rows appear in
/// first-recorded order), then one `ph: "X"` complete event per span.
/// Load the returned string in `chrome://tracing`, Perfetto, or check
/// it structurally with `scripts/check_trace_json.py`.
pub fn chrome_trace(spans: &[SpanRecord]) -> String {
    // rows: one tid per layer, in order of first appearance
    let mut tids: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut order: Vec<&'static str> = Vec::new();
    for s in spans {
        if !tids.contains_key(s.layer) {
            tids.insert(s.layer, order.len() as u64 + 1);
            order.push(s.layer);
        }
    }
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for layer in &order {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tids[layer],
            esc(layer)
        );
    }
    for s in spans {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\
             \"trace_id\":\"{:#018x}\",\"span_id\":\"{:#018x}\",\
             \"parent_id\":\"{:#018x}\",\"a0\":{}}}}}",
            esc(s.name),
            esc(s.layer),
            tids[s.layer],
            us(s.start_ns),
            us(s.dur_ns),
            s.trace_id,
            s.span_id,
            s.parent_id,
            s.a0
        );
    }
    out.push_str("]}");
    out
}

/// Human-readable span tree for one request: children indented under
/// their parents (by `parent_id`), siblings in start order, durations
/// in microseconds with `a0` shown when nonzero. Spans whose parent is
/// missing (e.g. overwritten in the ring) surface as extra roots rather
/// than vanishing.
pub fn flame_summary(spans: &[SpanRecord], trace_id: u64) -> String {
    let mut mine: Vec<&SpanRecord> = spans.iter().filter(|s| s.trace_id == trace_id).collect();
    mine.sort_by_key(|s| (s.start_ns, s.span_id));
    let have: std::collections::BTreeSet<u64> = mine.iter().map(|s| s.span_id).collect();
    let mut children: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    let mut roots: Vec<&SpanRecord> = Vec::new();
    for s in &mine {
        if s.parent_id != 0 && have.contains(&s.parent_id) && s.parent_id != s.span_id {
            children.entry(s.parent_id).or_default().push(s);
        } else {
            roots.push(s);
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "trace {:#018x} — {} span(s)", trace_id, mine.len());
    let mut visited: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    let mut stack: Vec<(&SpanRecord, usize)> = roots.iter().rev().map(|s| (*s, 0)).collect();
    while let Some((s, depth)) = stack.pop() {
        if !visited.insert(s.span_id) {
            continue; // cycle guard: malformed parent links can't hang us
        }
        let _ = write!(out, "{:indent$}{} [{}] {}us", "", s.name, s.layer, us(s.dur_ns), indent = depth * 2);
        if s.a0 != 0 {
            let _ = write!(out, " (a0={})", s.a0);
        }
        out.push('\n');
        if let Some(kids) = children.get(&s.span_id) {
            for k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    out
}

/// Map a registry name (`layer.noun.verb`) onto the Prometheus metric
/// charset: `[a-zA-Z0-9_:]`, everything else becomes `_`, and the
/// result gets an `fgp_` namespace prefix.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    out.push_str("fgp_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// Render a [`RegistrySnapshot`] in the Prometheus text exposition
/// format (content type `text/plain; version=0.0.4`).
///
/// * counters → `# TYPE fgp_x counter` + one sample line;
/// * gauges → `# TYPE fgp_x gauge` + one sample line (a gauge whose
///   sanitized name collides with a counter family is suffixed
///   `_gauge` — Prometheus forbids one name with two types);
/// * histograms → `# TYPE fgp_x_ns summary` + `quantile`-labelled
///   p50/p95/p99 bucket midpoints, `_sum` (count × mean, both already
///   integer ns) and `_count`.
///
/// Families are emitted sorted by *sanitized* name within each kind
/// (sanitizing can reorder around `.` vs digits), each `# TYPE` exactly
/// once, trailing newline included — the shape
/// `scripts/check_prom_text.py` pins in CI.
pub fn prometheus_text(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();

    let mut counters: Vec<(String, u64)> =
        snap.counters.iter().map(|c| (prom_name(&c.name), c.value)).collect();
    counters.sort();
    let counter_names: std::collections::BTreeSet<&str> =
        counters.iter().map(|(n, _)| n.as_str()).collect();
    for (name, value) in &counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {value}");
    }

    let mut gauges: Vec<(String, u64)> = snap
        .gauges
        .iter()
        .map(|g| {
            let mut n = prom_name(&g.name);
            if counter_names.contains(n.as_str()) {
                n.push_str("_gauge");
            }
            (n, g.value)
        })
        .collect();
    gauges.sort();
    for (name, value) in &gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {value}");
    }

    let mut hists: Vec<(String, &super::metrics::HistSummary)> =
        snap.histograms.iter().map(|h| (prom_name(&h.name) + "_ns", h)).collect();
    hists.sort_by(|a, b| a.0.cmp(&b.0));
    for (name, h) in &hists {
        let _ = writeln!(out, "# TYPE {name} summary");
        let _ = writeln!(out, "{name}{{quantile=\"0.5\"}} {}", h.p50_ns);
        let _ = writeln!(out, "{name}{{quantile=\"0.95\"}} {}", h.p95_ns);
        let _ = writeln!(out, "{name}{{quantile=\"0.99\"}} {}", h.p99_ns);
        let _ = writeln!(out, "{name}_sum {}", h.count.saturating_mul(h.mean_ns));
        let _ = writeln!(out, "{name}_count {}", h.count);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, id: u64, parent: u64, name: &'static str, layer: &'static str, start: u64, dur: u64) -> SpanRecord {
        SpanRecord {
            trace_id: trace,
            span_id: id,
            parent_id: parent,
            name,
            layer,
            start_ns: start,
            dur_ns: dur,
            a0: 0,
        }
    }

    #[test]
    fn chrome_trace_has_metadata_and_complete_events() {
        let spans = [
            span(1, 10, 0, "serve.request", "serve", 0, 5_000),
            span(1, 11, 10, "engine.execute", "engine", 1_000, 3_500),
        ];
        let json = chrome_trace(&spans);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"engine.execute\""));
        assert!(json.contains("\"ts\":1.000"));
        assert!(json.contains("\"dur\":3.500"));
        assert!(json.contains("\"trace_id\":\"0x0000000000000001\""));
        // two layers, two rows
        assert!(json.contains("\"args\":{\"name\":\"serve\"}"));
        assert!(json.contains("\"args\":{\"name\":\"engine\"}"));
    }

    #[test]
    fn chrome_trace_escapes_and_handles_empty() {
        assert_eq!(chrome_trace(&[]), "{\"traceEvents\":[]}");
        let s = [span(1, 2, 0, "quote\"backslash\\", "l", 0, 1)];
        let json = chrome_trace(&s);
        assert!(json.contains("quote\\\"backslash\\\\"));
    }

    #[test]
    fn flame_summary_indents_children_under_parents() {
        let spans = [
            span(7, 1, 0, "root", "serve", 0, 9_000),
            span(7, 2, 1, "child", "engine", 1_000, 4_000),
            span(7, 3, 2, "leaf", "fgp", 2_000, 1_000),
            span(8, 4, 0, "other-trace", "serve", 0, 1_000),
        ];
        let text = flame_summary(&spans, 7);
        assert!(text.contains("3 span(s)"));
        assert!(text.contains("\nroot [serve]"));
        assert!(text.contains("\n  child [engine]"));
        assert!(text.contains("\n    leaf [fgp]"));
        assert!(!text.contains("other-trace"));
    }

    #[test]
    fn flame_summary_orphans_become_roots_and_cycles_terminate() {
        let spans = [
            span(7, 2, 99, "orphan", "serve", 0, 100), // parent 99 not captured
            span(7, 5, 6, "a", "l", 10, 1),
            span(7, 6, 5, "b", "l", 11, 1), // a↔b cycle
        ];
        let text = flame_summary(&spans, 7);
        assert!(text.contains("orphan"));
        assert!(text.contains('a'));
    }

    #[test]
    fn prometheus_text_renders_all_three_kinds() {
        use crate::coordinator::Histogram;
        use std::time::Duration;
        let mut snap = RegistrySnapshot::new();
        snap.push_counter("serve.admitted", 41);
        snap.push_gauge("serve.inflight", 3);
        let h = Histogram::new();
        for _ in 0..8 {
            h.record(Duration::from_micros(1));
        }
        snap.push_histogram("serve.latency", &h);
        snap.sort();
        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE fgp_serve_admitted counter\nfgp_serve_admitted 41\n"));
        assert!(text.contains("# TYPE fgp_serve_inflight gauge\nfgp_serve_inflight 3\n"));
        assert!(text.contains("# TYPE fgp_serve_latency_ns summary\n"));
        assert!(text.contains("fgp_serve_latency_ns{quantile=\"0.5\"} "));
        assert!(text.contains("fgp_serve_latency_ns_count 8\n"));
        assert!(text.ends_with('\n'));
        // exactly one TYPE line per family
        assert_eq!(text.matches("# TYPE fgp_serve_latency_ns summary").count(), 1);
    }

    #[test]
    fn prometheus_text_sanitizes_and_disambiguates() {
        let mut snap = RegistrySnapshot::new();
        snap.push_counter("a.b-c", 1);
        snap.push_gauge("a.b-c", 2); // same sanitized family name as the counter
        snap.sort();
        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE fgp_a_b_c counter\nfgp_a_b_c 1\n"));
        assert!(text.contains("# TYPE fgp_a_b_c_gauge gauge\nfgp_a_b_c_gauge 2\n"));
    }

    #[test]
    fn prometheus_text_summary_sum_is_count_times_mean() {
        let mut snap = RegistrySnapshot::new();
        snap.histograms.push(crate::obs::HistSummary {
            name: "q".into(),
            count: 5,
            mean_ns: 700,
            p50_ns: 600,
            p95_ns: 900,
            p99_ns: 950,
        });
        let text = prometheus_text(&snap);
        assert!(text.contains("fgp_q_ns_sum 3500\n"));
        assert!(text.contains("fgp_q_ns_count 5\n"));
        assert!(prometheus_text(&RegistrySnapshot::new()).is_empty());
    }
}
