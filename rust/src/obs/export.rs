//! Exporters: Chrome trace-event JSON and a per-request flame summary.
//!
//! [`chrome_trace`] emits the Trace Event Format (the JSON that
//! `chrome://tracing` / Perfetto load): every [`SpanRecord`] becomes a
//! complete event (`ph: "X"`) with microsecond timestamps, one row
//! (`tid`) per recording layer, and the trace/span/parent ids in
//! `args` so the tree is recoverable in the UI. Device cycle spans are
//! recorded pre-rescaled onto the wall clock (cycles × 1/130 MHz — see
//! `FgpSimEngine`), so a compiled program's MMA/FAD phases render
//! inside the serving span that dispatched them.
//!
//! [`flame_summary`] is the terminal-sized version: one request's span
//! tree, indented, durations in microseconds — the "why was this chunk
//! slow" answer without leaving the shell.
//!
//! Both are hand-rolled JSON/text over `std::fmt` — the vendored set
//! has no serializer and the event shape is fixed.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::span::SpanRecord;

/// Escape a string for a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Microseconds with nanosecond precision, as a JSON number.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Render spans as Chrome trace-event JSON (`{"traceEvents": [...]}`).
///
/// One `ph: "M"` thread-name metadata event per layer (rows appear in
/// first-recorded order), then one `ph: "X"` complete event per span.
/// Load the returned string in `chrome://tracing`, Perfetto, or check
/// it structurally with `scripts/check_trace_json.py`.
pub fn chrome_trace(spans: &[SpanRecord]) -> String {
    // rows: one tid per layer, in order of first appearance
    let mut tids: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut order: Vec<&'static str> = Vec::new();
    for s in spans {
        if !tids.contains_key(s.layer) {
            tids.insert(s.layer, order.len() as u64 + 1);
            order.push(s.layer);
        }
    }
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for layer in &order {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tids[layer],
            esc(layer)
        );
    }
    for s in spans {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\
             \"trace_id\":\"{:#018x}\",\"span_id\":\"{:#018x}\",\
             \"parent_id\":\"{:#018x}\",\"a0\":{}}}}}",
            esc(s.name),
            esc(s.layer),
            tids[s.layer],
            us(s.start_ns),
            us(s.dur_ns),
            s.trace_id,
            s.span_id,
            s.parent_id,
            s.a0
        );
    }
    out.push_str("]}");
    out
}

/// Human-readable span tree for one request: children indented under
/// their parents (by `parent_id`), siblings in start order, durations
/// in microseconds with `a0` shown when nonzero. Spans whose parent is
/// missing (e.g. overwritten in the ring) surface as extra roots rather
/// than vanishing.
pub fn flame_summary(spans: &[SpanRecord], trace_id: u64) -> String {
    let mut mine: Vec<&SpanRecord> = spans.iter().filter(|s| s.trace_id == trace_id).collect();
    mine.sort_by_key(|s| (s.start_ns, s.span_id));
    let have: std::collections::BTreeSet<u64> = mine.iter().map(|s| s.span_id).collect();
    let mut children: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    let mut roots: Vec<&SpanRecord> = Vec::new();
    for s in &mine {
        if s.parent_id != 0 && have.contains(&s.parent_id) && s.parent_id != s.span_id {
            children.entry(s.parent_id).or_default().push(s);
        } else {
            roots.push(s);
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "trace {:#018x} — {} span(s)", trace_id, mine.len());
    let mut visited: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    let mut stack: Vec<(&SpanRecord, usize)> = roots.iter().rev().map(|s| (*s, 0)).collect();
    while let Some((s, depth)) = stack.pop() {
        if !visited.insert(s.span_id) {
            continue; // cycle guard: malformed parent links can't hang us
        }
        let _ = write!(out, "{:indent$}{} [{}] {}us", "", s.name, s.layer, us(s.dur_ns), indent = depth * 2);
        if s.a0 != 0 {
            let _ = write!(out, " (a0={})", s.a0);
        }
        out.push('\n');
        if let Some(kids) = children.get(&s.span_id) {
            for k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, id: u64, parent: u64, name: &'static str, layer: &'static str, start: u64, dur: u64) -> SpanRecord {
        SpanRecord {
            trace_id: trace,
            span_id: id,
            parent_id: parent,
            name,
            layer,
            start_ns: start,
            dur_ns: dur,
            a0: 0,
        }
    }

    #[test]
    fn chrome_trace_has_metadata_and_complete_events() {
        let spans = [
            span(1, 10, 0, "serve.request", "serve", 0, 5_000),
            span(1, 11, 10, "engine.execute", "engine", 1_000, 3_500),
        ];
        let json = chrome_trace(&spans);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"engine.execute\""));
        assert!(json.contains("\"ts\":1.000"));
        assert!(json.contains("\"dur\":3.500"));
        assert!(json.contains("\"trace_id\":\"0x0000000000000001\""));
        // two layers, two rows
        assert!(json.contains("\"args\":{\"name\":\"serve\"}"));
        assert!(json.contains("\"args\":{\"name\":\"engine\"}"));
    }

    #[test]
    fn chrome_trace_escapes_and_handles_empty() {
        assert_eq!(chrome_trace(&[]), "{\"traceEvents\":[]}");
        let s = [span(1, 2, 0, "quote\"backslash\\", "l", 0, 1)];
        let json = chrome_trace(&s);
        assert!(json.contains("quote\\\"backslash\\\\"));
    }

    #[test]
    fn flame_summary_indents_children_under_parents() {
        let spans = [
            span(7, 1, 0, "root", "serve", 0, 9_000),
            span(7, 2, 1, "child", "engine", 1_000, 4_000),
            span(7, 3, 2, "leaf", "fgp", 2_000, 1_000),
            span(8, 4, 0, "other-trace", "serve", 0, 1_000),
        ];
        let text = flame_summary(&spans, 7);
        assert!(text.contains("3 span(s)"));
        assert!(text.contains("\nroot [serve]"));
        assert!(text.contains("\n  child [engine]"));
        assert!(text.contains("\n    leaf [fgp]"));
        assert!(!text.contains("other-trace"));
    }

    #[test]
    fn flame_summary_orphans_become_roots_and_cycles_terminate() {
        let spans = [
            span(7, 2, 99, "orphan", "serve", 0, 100), // parent 99 not captured
            span(7, 5, 6, "a", "l", 10, 1),
            span(7, 6, 5, "b", "l", 11, 1), // a↔b cycle
        ];
        let text = flame_summary(&spans, 7);
        assert!(text.contains("orphan"));
        assert!(text.contains('a'));
    }
}
