//! Trace contexts and the lock-free span recorder.
//!
//! A [`TraceContext`] names one request (`trace_id`) and one node in its
//! span tree (`span_id`). The convention everywhere in this crate is
//! **parent-handle**: the context a component *receives* identifies the
//! span that called it; the component mints children with
//! [`TraceContext::child`] and records its own work with
//! `parent_id = received.span_id`. One request therefore yields one
//! tree, no matter how many threads and devices it crossed.
//!
//! Spans land in a [`SpanRing`] — a fixed-capacity ring of slots, each
//! guarded by a one-byte busy latch. Writers never block: a slot that
//! loses its CAS is counted in `dropped` and the record is discarded,
//! which bounds both memory and worst-case interference with the
//! request path. The [`Telemetry`] handle bundles the ring with a
//! monotonic epoch and the [`MetricsRegistry`]; when
//! [`TelemetryConfig::enabled`] is false every hook is a single branch
//! and no clock is read (the overhead gate in `BENCH_obs.json`).

use std::cell::UnsafeCell;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use super::metrics::MetricsRegistry;

/// Global span-id sequence; hashed so ids from concurrent mints don't
/// collide and don't leak ordering.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// SplitMix64 — the standard 64-bit finalizer; enough mixing to make
/// sequential seeds look independent, with no state beyond the seed.
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One fresh nonzero id.
fn fresh_id() -> u64 {
    let seed = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    splitmix64(seed).max(1)
}

/// The identity a request carries across layers (and the wire):
/// which request this is, and which span is the caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceContext {
    /// Request identity — constant across every span of one request.
    pub trace_id: u64,
    /// The span this context was minted by (the parent handle).
    pub span_id: u64,
}

impl TraceContext {
    /// Mint a fresh root context (new `trace_id`, new `span_id`).
    pub fn mint() -> Self {
        TraceContext { trace_id: fresh_id(), span_id: fresh_id() }
    }

    /// A child context: same request, fresh `span_id`.
    pub fn child(&self) -> Self {
        TraceContext { trace_id: self.trace_id, span_id: fresh_id() }
    }
}

/// One completed span, fixed-size and `Copy` so ring slots never
/// allocate. `name`/`layer` are `&'static str` by design: span names
/// are code, not data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Request identity.
    pub trace_id: u64,
    /// This span's identity (0 ⇒ empty slot).
    pub span_id: u64,
    /// Parent span (0 ⇒ root).
    pub parent_id: u64,
    /// What happened (e.g. `"serve.gate"`, `"engine.execute"`).
    pub name: &'static str,
    /// Which layer recorded it (`"client"`, `"serve"`, `"engine"`, ...).
    pub layer: &'static str,
    /// Start, nanoseconds since the [`Telemetry`] epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// One free attribute (cycles, batch size, sample count — per span).
    pub a0: u64,
}

impl Default for SpanRecord {
    fn default() -> Self {
        SpanRecord {
            trace_id: 0,
            span_id: 0,
            parent_id: 0,
            name: "",
            layer: "",
            start_ns: 0,
            dur_ns: 0,
            a0: 0,
        }
    }
}

/// One ring slot: a spin-free busy latch over the record.
struct Slot {
    busy: AtomicBool,
    rec: UnsafeCell<SpanRecord>,
}

/// Fixed-capacity, lock-free span recorder. Writers claim a slot by
/// index (`head` fetch-add) and a CAS on the slot latch; a lost CAS
/// increments `dropped` instead of waiting, so recording is
/// obstruction-free and never blocks the request path.
pub struct SpanRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
    dropped: AtomicU64,
}

// SAFETY: the record cell is only written between a successful
// false→true CAS on `busy` (Acquire) and the Release store back to
// false; readers take the same latch. No two threads touch a cell
// concurrently.
unsafe impl Sync for SpanRing {}
unsafe impl Send for SpanRing {}

impl SpanRing {
    /// Ring with room for `capacity` spans (0 drops everything).
    pub fn new(capacity: usize) -> Self {
        SpanRing {
            slots: (0..capacity)
                .map(|_| Slot { busy: AtomicBool::new(false), rec: UnsafeCell::new(SpanRecord::default()) })
                .collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans discarded (zero capacity or a contended slot).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one span; never blocks.
    pub fn record(&self, rec: SpanRecord) {
        let len = self.slots.len() as u64;
        if len == 0 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = (self.head.fetch_add(1, Ordering::Relaxed) % len) as usize;
        let slot = &self.slots[idx];
        if slot
            .busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: latch held (see the `Sync` impl).
        unsafe { *slot.rec.get() = rec };
        slot.busy.store(false, Ordering::Release);
    }

    /// Non-destructive copy of every recorded span, oldest timestamp
    /// first. Slots a writer holds at snapshot time are skipped (they
    /// are mid-write); empty slots (`span_id == 0`) are filtered.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            if slot
                .busy
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // SAFETY: latch held (see the `Sync` impl).
            let rec = unsafe { *slot.rec.get() };
            slot.busy.store(false, Ordering::Release);
            if rec.span_id != 0 {
                out.push(rec);
            }
        }
        out.sort_by_key(|r| (r.start_ns, r.span_id));
        out
    }

    /// Empty every slot (the drop counter is kept — it is cumulative).
    pub fn clear(&self) {
        for slot in self.slots.iter() {
            if slot
                .busy
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // SAFETY: latch held (see the `Sync` impl).
            unsafe { *slot.rec.get() = SpanRecord::default() };
            slot.busy.store(false, Ordering::Release);
        }
    }
}

impl fmt::Debug for SpanRing {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SpanRing")
            .field("capacity", &self.slots.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

/// Telemetry switches, embedded in `ServeConfig`/`FgpFarm` setup.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    /// Master switch. Off (the default) ⇒ no spans, no clock reads, no
    /// profiler attach — results are bitwise identical to an
    /// uninstrumented build (invariant 7).
    pub enabled: bool,
    /// Span-ring capacity when enabled.
    pub span_capacity: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: false, span_capacity: 4096 }
    }
}

impl TelemetryConfig {
    /// Everything on, default capacity.
    pub fn on() -> Self {
        TelemetryConfig { enabled: true, ..TelemetryConfig::default() }
    }
}

/// The per-deployment telemetry handle: one monotonic epoch, one span
/// ring, one metrics registry — shared (via `Arc`) by the serve tier,
/// the farm devices and the engine sessions so their spans land on one
/// timeline and their counters in one table.
///
/// Counters in [`Telemetry::registry`] work even when spans are
/// disabled (they are the `STATS` wire reply); only span recording and
/// the per-instruction profiler are gated by the switch.
#[derive(Debug)]
pub struct Telemetry {
    config: TelemetryConfig,
    epoch: Instant,
    spans: SpanRing,
    registry: MetricsRegistry,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new(TelemetryConfig::default())
    }
}

impl Telemetry {
    /// Handle under `config` (ring allocated only when enabled).
    pub fn new(config: TelemetryConfig) -> Self {
        let cap = if config.enabled { config.span_capacity } else { 0 };
        Telemetry {
            config,
            epoch: Instant::now(),
            spans: SpanRing::new(cap),
            registry: MetricsRegistry::new(),
        }
    }

    /// Is span recording on?
    pub fn enabled(&self) -> bool {
        self.config.enabled
    }

    /// The active configuration.
    pub fn config(&self) -> TelemetryConfig {
        self.config
    }

    /// Nanoseconds since this handle's epoch — the timestamp every span
    /// uses. Returns 0 when disabled so gated callers skip the clock
    /// read entirely.
    pub fn now_ns(&self) -> u64 {
        if !self.config.enabled {
            return 0;
        }
        self.epoch.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64
    }

    /// The span ring.
    pub fn spans(&self) -> &SpanRing {
        &self.spans
    }

    /// The unified metrics registry (live even when spans are off).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Record a span that started at `start_ns` (from [`Telemetry::now_ns`])
    /// and ends now. No-op when disabled.
    pub fn span(
        &self,
        ctx: TraceContext,
        parent_id: u64,
        name: &'static str,
        layer: &'static str,
        start_ns: u64,
        a0: u64,
    ) {
        if !self.config.enabled {
            return;
        }
        let dur_ns = self.now_ns().saturating_sub(start_ns);
        self.span_at(ctx, parent_id, name, layer, start_ns, dur_ns, a0);
    }

    /// Record a span with an explicit duration — the hook device-cycle
    /// phases use after rescaling cycles onto the wall clock. No-op
    /// when disabled.
    #[allow(clippy::too_many_arguments)]
    pub fn span_at(
        &self,
        ctx: TraceContext,
        parent_id: u64,
        name: &'static str,
        layer: &'static str,
        start_ns: u64,
        dur_ns: u64,
        a0: u64,
    ) {
        if !self.config.enabled {
            return;
        }
        self.spans.record(SpanRecord {
            trace_id: ctx.trace_id,
            span_id: ctx.span_id,
            parent_id,
            name,
            layer,
            start_ns,
            dur_ns,
            a0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mint_and_child_share_trace_id() {
        let root = TraceContext::mint();
        let child = root.child();
        assert_eq!(root.trace_id, child.trace_id);
        assert_ne!(root.span_id, child.span_id);
        assert_ne!(root.trace_id, 0);
        assert_ne!(TraceContext::mint().trace_id, root.trace_id);
    }

    #[test]
    fn ring_records_snapshots_and_wraps() {
        let ring = SpanRing::new(4);
        for i in 0..6u64 {
            ring.record(SpanRecord {
                trace_id: 1,
                span_id: i + 1,
                start_ns: i,
                ..SpanRecord::default()
            });
        }
        let snap = ring.snapshot();
        // capacity 4, six writes: the oldest two were overwritten
        assert_eq!(snap.len(), 4);
        assert!(snap.iter().all(|r| r.span_id >= 3));
        assert!(snap.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));
        ring.clear();
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn zero_capacity_ring_counts_drops() {
        let ring = SpanRing::new(0);
        ring.record(SpanRecord { span_id: 1, ..SpanRecord::default() });
        assert_eq!(ring.dropped(), 1);
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn concurrent_writers_never_lose_the_ring() {
        let ring = Arc::new(SpanRing::new(64));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let ring = Arc::clone(&ring);
            joins.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    ring.record(SpanRecord {
                        trace_id: t + 1,
                        span_id: t * 1000 + i + 1,
                        ..SpanRecord::default()
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = ring.snapshot();
        assert!(snap.len() <= 64);
        assert!(snap.iter().all(|r| r.span_id != 0));
    }

    #[test]
    fn disabled_telemetry_is_inert() {
        let tel = Telemetry::new(TelemetryConfig::default());
        assert!(!tel.enabled());
        assert_eq!(tel.now_ns(), 0);
        tel.span(TraceContext::mint(), 0, "x", "test", 0, 0);
        assert!(tel.spans().snapshot().is_empty());
        assert_eq!(tel.spans().dropped(), 0, "disabled span() must not even touch the ring");
        // counters still work with spans off — they back the STATS reply
        tel.registry().add("still.counting", 2);
        assert_eq!(tel.registry().snapshot().counter("still.counting"), Some(2));
    }

    #[test]
    fn enabled_telemetry_records_wall_spans() {
        let tel = Telemetry::new(TelemetryConfig::on());
        let ctx = TraceContext::mint();
        let t0 = tel.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(1));
        tel.span(ctx, 0, "work", "test", t0, 7);
        let snap = tel.spans().snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].name, "work");
        assert_eq!(snap[0].a0, 7);
        assert!(snap[0].dur_ns >= 1_000_000, "slept 1ms inside the span");
    }
}
