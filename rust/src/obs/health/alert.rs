//! Structured alert events and pluggable delivery sinks.
//!
//! An [`Alert`] is the watcher's only output type: every detector
//! transition — firing after `fire_after` consecutive breaches,
//! resolved after `resolve_after` consecutive clears (see
//! [`watch`](super::watch)) — becomes one structured event carrying the
//! detector kind, the subject it judged (a tenant, a device, a global
//! surface), the observed value and the threshold it crossed. Events
//! fan out to [`AlertSink`]s; the serving tier keeps the active set for
//! the wire `Health` reply.

use std::fmt;
use std::sync::Mutex;

/// Which detector produced an alert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertKind {
    /// Serving p99 latency regressed past the rolling EWMA baseline.
    P99Regression,
    /// The admission window is (nearly) saturated — requests are about
    /// to be rejected `Busy`.
    AdmissionSaturation,
    /// The engine program-cache hit rate collapsed — recompiles on the
    /// hot path.
    CacheHitCollapse,
    /// One farm device is a latency/error outlier vs. its peers.
    DeviceOutlier,
    /// A tenant is burning its SLO error budget on both the short and
    /// long windows.
    SloBurn,
}

impl AlertKind {
    /// Stable lower-snake name (exposition + report rendering).
    pub fn as_str(&self) -> &'static str {
        match self {
            AlertKind::P99Regression => "p99_regression",
            AlertKind::AdmissionSaturation => "admission_saturation",
            AlertKind::CacheHitCollapse => "cache_hit_collapse",
            AlertKind::DeviceOutlier => "device_outlier",
            AlertKind::SloBurn => "slo_burn",
        }
    }
}

/// Firing edge or resolution edge — alerts are only emitted on
/// transitions, never re-emitted while a condition persists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertState {
    /// The condition held for `fire_after` consecutive snapshots.
    Firing,
    /// A previously-firing condition cleared for `resolve_after`
    /// consecutive snapshots.
    Resolved,
}

/// How urgently an operator should care.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertSeverity {
    /// Degradation that routing/backpressure is expected to absorb.
    Warning,
    /// Objective breach — user-visible if it persists.
    Critical,
}

/// One structured alert event.
#[derive(Clone, Debug, PartialEq)]
pub struct Alert {
    /// Detector that produced the event.
    pub kind: AlertKind,
    /// Firing or resolved edge.
    pub state: AlertState,
    /// Operator urgency.
    pub severity: AlertSeverity,
    /// What was judged: `"serve"`, `"tenant.<name>"`, `"farm.device<i>"`.
    pub subject: String,
    /// Observed value at the transition (units depend on `kind`).
    pub value: f64,
    /// Threshold the value crossed.
    pub threshold: f64,
    /// Watcher-epoch timestamp of the transition, nanoseconds.
    pub t_ns: u64,
    /// Human-readable one-liner.
    pub message: String,
}

impl fmt::Display for Alert {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let state = match self.state {
            AlertState::Firing => "FIRING",
            AlertState::Resolved => "resolved",
        };
        let sev = match self.severity {
            AlertSeverity::Warning => "warn",
            AlertSeverity::Critical => "crit",
        };
        write!(
            f,
            "[{state}/{sev}] {} {}: {} (value {:.3}, threshold {:.3})",
            self.kind.as_str(),
            self.subject,
            self.message,
            self.value,
            self.threshold
        )
    }
}

/// Where alert transitions go. Implementations must tolerate being
/// called from the watcher thread (keep `emit` quick and non-blocking).
pub trait AlertSink: Send + Sync {
    /// Deliver one transition event.
    fn emit(&self, alert: &Alert);
}

impl<S: AlertSink + ?Sized> AlertSink for std::sync::Arc<S> {
    fn emit(&self, alert: &Alert) {
        (**self).emit(alert);
    }
}

/// Test/bench sink: collects every event in order behind a mutex.
#[derive(Debug, Default)]
pub struct VecSink {
    events: Mutex<Vec<Alert>>,
}

impl VecSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Copy of everything emitted so far.
    pub fn events(&self) -> Vec<Alert> {
        self.events.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Number of events emitted so far.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// No events yet?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl AlertSink for VecSink {
    fn emit(&self, alert: &Alert) {
        self.events.lock().unwrap_or_else(|p| p.into_inner()).push(alert.clone());
    }
}

/// Operator sink: one line per transition on stderr.
#[derive(Clone, Copy, Debug, Default)]
pub struct StderrSink;

impl AlertSink for StderrSink {
    fn emit(&self, alert: &Alert) {
        eprintln!("{alert}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn alert(state: AlertState) -> Alert {
        Alert {
            kind: AlertKind::DeviceOutlier,
            state,
            severity: AlertSeverity::Warning,
            subject: "farm.device1".to_string(),
            value: 9.0,
            threshold: 8.0,
            t_ns: 42,
            message: "latency outlier".to_string(),
        }
    }

    #[test]
    fn vec_sink_collects_in_order() {
        let sink = VecSink::new();
        assert!(sink.is_empty());
        sink.emit(&alert(AlertState::Firing));
        sink.emit(&alert(AlertState::Resolved));
        let ev = sink.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].state, AlertState::Firing);
        assert_eq!(ev[1].state, AlertState::Resolved);
    }

    #[test]
    fn arc_sinks_are_sinks_too() {
        let sink = Arc::new(VecSink::new());
        let as_dyn: &dyn AlertSink = &sink;
        as_dyn.emit(&alert(AlertState::Firing));
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn display_is_grep_friendly() {
        let text = alert(AlertState::Firing).to_string();
        assert!(text.contains("FIRING"));
        assert!(text.contains("device_outlier"));
        assert!(text.contains("farm.device1"));
        let resolved = alert(AlertState::Resolved).to_string();
        assert!(resolved.contains("resolved"));
    }
}
