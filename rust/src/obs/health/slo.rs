//! Per-tenant SLO definitions and multi-window burn-rate evaluation.
//!
//! An [`SloDef`] pins two promises per tenant: a p99 latency objective
//! and an error budget (the fraction of requests allowed to be
//! rejected). Evaluation follows the multi-window burn-rate recipe:
//! the *burn rate* is the windowed error rate divided by the budget —
//! burn 1.0 means the tenant is consuming budget exactly as fast as it
//! accrues, burn 10 means ten times faster. A short window reacts
//! quickly; a long window keeps one admission blip from paging anyone.
//! The [`watch`](super::watch) detector fires `SloBurn` only when
//! *both* windows burn ≥ 1.
//!
//! Inputs are windowed *deltas* of [`RegistrySnapshot`] counters
//! (`tenant.<name>.requests` / `.rejected_quota` / `.rejected_busy`),
//! so evaluation is a pure function of two snapshots — no clocks, no
//! locks, unit-testable without a server. The latency leg reads the
//! cumulative `serve.latency` p99 (the registry keeps one global
//! serving histogram; per-tenant latency splits are future work), so
//! it reflects lifetime-so-far tails rather than a window.

use crate::obs::RegistrySnapshot;

/// One tenant's service-level objective.
#[derive(Clone, Debug, PartialEq)]
pub struct SloDef {
    /// Tenant name (matches the admission ledger).
    pub tenant: String,
    /// p99 latency objective in nanoseconds (0 = no latency objective).
    pub p99_objective_ns: u64,
    /// Error budget: allowed rejected fraction of requests, e.g. 0.01.
    pub error_budget: f64,
}

impl SloDef {
    /// Convenience constructor.
    pub fn new(tenant: &str, p99_objective_ns: u64, error_budget: f64) -> Self {
        SloDef { tenant: tenant.to_string(), p99_objective_ns, error_budget }
    }
}

/// Point-in-time SLO evaluation for one tenant — what the wire `Health`
/// reply carries.
#[derive(Clone, Debug, PartialEq)]
pub struct SloStatus {
    /// Tenant name.
    pub tenant: String,
    /// The latency objective being judged against (ns, 0 = none).
    pub p99_objective_ns: u64,
    /// The error budget being judged against.
    pub error_budget: f64,
    /// Observed cumulative serving p99 (ns, 0 = no latency data yet).
    pub p99_ns: u64,
    /// Burn rate over the short window.
    pub burn_short: f64,
    /// Burn rate over the long window.
    pub burn_long: f64,
    /// Cumulative requests observed for this tenant.
    pub requests: u64,
    /// Cumulative rejections (quota + busy) for this tenant.
    pub errors: u64,
    /// Within objective: latency under the objective (when both are
    /// known) and not burning budget on both windows at once.
    pub healthy: bool,
}

fn counter(snap: &RegistrySnapshot, name: &str) -> u64 {
    snap.counter(name).unwrap_or(0)
}

/// Windowed (requests, errors) deltas for `tenant` between two
/// snapshots (`base` earlier, `newest` later). Counters are monotone;
/// saturating subtraction guards a restarted registry.
pub fn tenant_deltas(
    tenant: &str,
    newest: &RegistrySnapshot,
    base: &RegistrySnapshot,
) -> (u64, u64) {
    let req = format!("tenant.{tenant}.requests");
    let quota = format!("tenant.{tenant}.rejected_quota");
    let busy = format!("tenant.{tenant}.rejected_busy");
    let d = |name: &str| counter(newest, name).saturating_sub(counter(base, name));
    (d(&req), d(&quota) + d(&busy))
}

/// Burn rate from windowed deltas: `(errors/requests) / budget`.
/// Zero-request windows and non-positive budgets burn 0 (nothing to
/// judge / nothing promised).
pub fn burn_rate(requests_delta: u64, errors_delta: u64, budget: f64) -> f64 {
    if requests_delta == 0 || budget <= 0.0 {
        return 0.0;
    }
    (errors_delta as f64 / requests_delta as f64) / budget
}

/// Evaluate one SLO from the newest snapshot plus the short- and
/// long-window base snapshots (what the watcher ring hands us).
pub fn evaluate(
    def: &SloDef,
    newest: &RegistrySnapshot,
    short_base: &RegistrySnapshot,
    long_base: &RegistrySnapshot,
) -> SloStatus {
    let (req_s, err_s) = tenant_deltas(&def.tenant, newest, short_base);
    let (req_l, err_l) = tenant_deltas(&def.tenant, newest, long_base);
    let burn_short = burn_rate(req_s, err_s, def.error_budget);
    let burn_long = burn_rate(req_l, err_l, def.error_budget);
    let p99_ns = newest.histogram("serve.latency").map(|h| h.p99_ns).unwrap_or(0);
    let latency_ok = def.p99_objective_ns == 0 || p99_ns == 0 || p99_ns <= def.p99_objective_ns;
    let burning = burn_short >= 1.0 && burn_long >= 1.0;
    SloStatus {
        tenant: def.tenant.clone(),
        p99_objective_ns: def.p99_objective_ns,
        error_budget: def.error_budget,
        p99_ns,
        burn_short,
        burn_long,
        requests: counter(newest, &format!("tenant.{}.requests", def.tenant)),
        errors: counter(newest, &format!("tenant.{}.rejected_quota", def.tenant))
            + counter(newest, &format!("tenant.{}.rejected_busy", def.tenant)),
        healthy: latency_ok && !burning,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(req: u64, quota: u64, busy: u64) -> RegistrySnapshot {
        let mut s = RegistrySnapshot::new();
        s.push_counter("tenant.acme.requests", req);
        s.push_counter("tenant.acme.rejected_quota", quota);
        s.push_counter("tenant.acme.rejected_busy", busy);
        s.sort();
        s
    }

    #[test]
    fn burn_rate_is_error_rate_over_budget() {
        // 2% errors against a 1% budget: burning twice as fast as accrual
        assert!((burn_rate(100, 2, 0.01) - 2.0).abs() < 1e-12);
        assert_eq!(burn_rate(0, 0, 0.01), 0.0, "empty window burns nothing");
        assert_eq!(burn_rate(100, 2, 0.0), 0.0, "no budget promised, no burn");
    }

    #[test]
    fn tenant_deltas_are_windowed_and_saturating() {
        let base = snap(100, 1, 0);
        let newest = snap(150, 6, 2);
        assert_eq!(tenant_deltas("acme", &newest, &base), (50, 7));
        // restarted registry: newest below base must not underflow
        assert_eq!(tenant_deltas("acme", &base, &newest), (0, 0));
    }

    #[test]
    fn evaluate_flags_burning_only_on_both_windows() {
        let def = SloDef::new("acme", 0, 0.01);
        let long_base = snap(0, 0, 0);
        let short_base = snap(900, 0, 0);
        // short window: 100 requests, 5 errors → burn 5.0;
        // long window: 1000 requests, 5 errors → burn 0.5 → still healthy
        let newest = snap(1000, 5, 0);
        let st = evaluate(&def, &newest, &short_base, &long_base);
        assert!(st.burn_short > 1.0 && st.burn_long < 1.0);
        assert!(st.healthy, "one hot window alone must not flag");
        // both windows burning → unhealthy
        let st2 = evaluate(&def, &snap(1000, 20, 0), &short_base, &long_base);
        assert!(st2.burn_short >= 1.0 && st2.burn_long >= 1.0);
        assert!(!st2.healthy);
        assert_eq!(st2.requests, 1000);
        assert_eq!(st2.errors, 20);
    }

    #[test]
    fn evaluate_judges_latency_against_objective() {
        let mut newest = snap(10, 0, 0);
        newest.histograms.push(crate::obs::HistSummary {
            name: "serve.latency".into(),
            count: 10,
            mean_ns: 500,
            p50_ns: 400,
            p95_ns: 900,
            p99_ns: 1500,
        });
        newest.sort();
        let base = snap(0, 0, 0);
        let tight = SloDef::new("acme", 1000, 0.01);
        assert!(!evaluate(&tight, &newest, &base, &base).healthy, "p99 1500 > objective 1000");
        let loose = SloDef::new("acme", 2000, 0.01);
        assert!(evaluate(&loose, &newest, &base, &base).healthy);
        let none = SloDef::new("acme", 0, 0.01);
        assert!(evaluate(&none, &newest, &base, &base).healthy, "objective 0 = no latency SLO");
    }
}
