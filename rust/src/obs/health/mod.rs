//! S12 — Operational intelligence: SLOs, burn-rate alerting, anomaly
//! detection, and the health scores behind health-aware routing.
//!
//! PR 7's telemetry made the serving tier *visible*; this layer makes
//! it *judged*. Ortiz et al. (PAPERS.md) pitch Gaussian message
//! passing for emerging hardware precisely because node-local
//! computation tolerates per-node degradation — but only if the system
//! can see the degradation and move work away from it. Three pieces,
//! std-only like the rest of the crate:
//!
//! * [`slo`] — per-tenant [`SloDef`]s (latency objective + error
//!   budget) evaluated with multi-window burn rates over windowed
//!   [`RegistrySnapshot`](crate::obs::RegistrySnapshot) deltas;
//! * [`watch`] — [`HealthState`]: a fixed-capacity snapshot ring, the
//!   anomaly detectors (p99-vs-EWMA regression, admission saturation,
//!   cache-hit collapse, per-device outliers, SLO burn) and
//!   firing/resolved hysteresis. The serving tier samples into it from
//!   a background watcher thread;
//! * [`alert`] — structured [`Alert`] transitions fanned out to
//!   pluggable [`AlertSink`]s.
//!
//! The pinned contract extends ARCHITECTURE.md invariant 7: health off
//! (the default) ⇒ bitwise-identical served outputs, **no watcher
//! thread, and no clock reads** — every hook reduces to one branch.
//! [`device_score`] is the routing signal: pure arithmetic over the
//! farm's per-device request/error/EWMA-latency stats, so
//! `FgpServe` can drain sticky streams off a degraded-but-alive device
//! before it hard-fails.

pub mod alert;
pub mod slo;
pub mod watch;

pub use alert::{Alert, AlertKind, AlertSeverity, AlertSink, AlertState, StderrSink, VecSink};
pub use slo::{burn_rate, SloDef, SloStatus};
pub use watch::{HealthState, SnapshotPoint, WatchConfig};

/// Operational-intelligence switchboard, carried inside the serving
/// tier's config. Defaults to **off**: no watcher thread is spawned, no
/// clocks are read, and served outputs are bitwise-identical to a build
/// without this module (invariant 7 extension).
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Master switch for the watcher thread, device health tracking and
    /// health-aware routing.
    pub enabled: bool,
    /// Routing threshold: sticky streams drain off devices whose
    /// [`device_score`] falls below this (0 disables draining).
    pub min_device_score: f64,
    /// Watcher cadence and detector thresholds.
    pub watch: WatchConfig,
    /// Per-tenant SLOs to evaluate.
    pub slos: Vec<SloDef>,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: false,
            min_device_score: 0.5,
            watch: WatchConfig::default(),
            slos: Vec::new(),
        }
    }
}

impl HealthConfig {
    /// Enabled with default thresholds and no SLOs.
    pub fn on() -> Self {
        HealthConfig { enabled: true, ..HealthConfig::default() }
    }
}

/// One farm device's health as seen by routing and the wire `Health`
/// reply.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceHealth {
    /// Device index in the farm.
    pub device: u32,
    /// Still alive (dead devices score 0 and are never picked)?
    pub live: bool,
    /// Requests dispatched to this device.
    pub requests: u64,
    /// Retryable errors observed from this device.
    pub errors: u64,
    /// EWMA request latency, nanoseconds (0 until the first sample).
    pub ewma_ns: u64,
    /// Routing score in [0, 1] — see [`device_score`].
    pub score: f64,
}

/// Routing score for one device: `1 − error_rate`, scaled down by how
/// much slower than the live-peer median the device is
/// (`median/ewma` when `ewma > median`). Dead devices score 0; devices
/// with no latency sample yet keep the error-only score. Pure
/// arithmetic — no clocks, unit-testable, and cheap enough to run on
/// every pick.
pub fn device_score(
    live: bool,
    requests: u64,
    errors: u64,
    ewma_ns: u64,
    median_ewma_ns: u64,
) -> f64 {
    if !live {
        return 0.0;
    }
    let total = requests + errors;
    let mut score = if total == 0 { 1.0 } else { 1.0 - errors as f64 / total as f64 };
    if median_ewma_ns > 0 && ewma_ns > median_ewma_ns {
        score *= median_ewma_ns as f64 / ewma_ns as f64;
    }
    score.clamp(0.0, 1.0)
}

/// Everything the wire `Health` reply carries: per-tenant SLO status,
/// active alerts, per-device health, and watcher totals.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct HealthSnapshot {
    /// Is the health layer running on the server?
    pub enabled: bool,
    /// Watcher snapshots observed so far.
    pub snapshots: u64,
    /// Alerts fired so far (lifetime, resolutions not counted).
    pub alerts_total: u64,
    /// Per-tenant SLO evaluations.
    pub slos: Vec<SloStatus>,
    /// Currently-firing alerts.
    pub alerts: Vec<Alert>,
    /// Per-device health/routing scores.
    pub devices: Vec<DeviceHealth>,
}

impl HealthSnapshot {
    /// The reply a server with the health layer off returns (device
    /// identity is still useful for `fgp health` against such servers).
    pub fn disabled(devices: Vec<DeviceHealth>) -> Self {
        HealthSnapshot { enabled: false, devices, ..HealthSnapshot::default() }
    }

    /// Render the operator-facing text report (`fgp health`,
    /// `examples/monitor_farm.rs`).
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "health: {} — {} snapshot(s), {} alert(s) fired",
            if self.enabled { "enabled" } else { "disabled" },
            self.snapshots,
            self.alerts_total
        );
        for s in &self.slos {
            let _ = writeln!(
                out,
                "  slo {}: {} — p99 {}ns (objective {}ns), burn {:.2}×/{:.2}×, {}/{} rejected",
                s.tenant,
                if s.healthy { "OK" } else { "BREACH" },
                s.p99_ns,
                s.p99_objective_ns,
                s.burn_short,
                s.burn_long,
                s.errors,
                s.requests
            );
        }
        if self.alerts.is_empty() {
            let _ = writeln!(out, "  alerts: none firing");
        }
        for a in &self.alerts {
            let _ = writeln!(out, "  alert: {a}");
        }
        for d in &self.devices {
            let _ = writeln!(
                out,
                "  device {}: {} score {:.2} — {} req, {} err, ewma {}ns",
                d.device,
                if d.live { "live" } else { "DEAD" },
                d.score,
                d.requests,
                d.errors,
                d.ewma_ns
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_off_and_on_flips_only_the_switch() {
        let d = HealthConfig::default();
        assert!(!d.enabled);
        let on = HealthConfig::on();
        assert!(on.enabled);
        assert_eq!(on.min_device_score, d.min_device_score);
    }

    #[test]
    fn device_score_shape() {
        assert_eq!(device_score(false, 100, 0, 1000, 1000), 0.0, "dead scores 0");
        assert_eq!(device_score(true, 0, 0, 0, 0), 1.0, "fresh device scores 1");
        assert_eq!(device_score(true, 90, 10, 0, 0), 0.9, "error rate subtracts");
        // 8× slower than the median: score scaled by 1/8
        let slow = device_score(true, 100, 0, 8_000, 1_000);
        assert!((slow - 0.125).abs() < 1e-12);
        // faster than median: no penalty
        assert_eq!(device_score(true, 100, 0, 500, 1_000), 1.0);
        // both penalties compose
        let both = device_score(true, 50, 50, 2_000, 1_000);
        assert!((both - 0.25).abs() < 1e-12);
    }

    #[test]
    fn report_renders_all_sections() {
        let snap = HealthSnapshot {
            enabled: true,
            snapshots: 12,
            alerts_total: 1,
            slos: vec![SloStatus {
                tenant: "acme".into(),
                p99_objective_ns: 1000,
                error_budget: 0.01,
                p99_ns: 500,
                burn_short: 0.0,
                burn_long: 0.0,
                requests: 10,
                errors: 0,
                healthy: true,
            }],
            alerts: vec![],
            devices: vec![DeviceHealth {
                device: 0,
                live: true,
                requests: 10,
                errors: 0,
                ewma_ns: 900,
                score: 1.0,
            }],
        };
        let text = snap.report();
        assert!(text.contains("health: enabled"));
        assert!(text.contains("slo acme: OK"));
        assert!(text.contains("alerts: none firing"));
        assert!(text.contains("device 0: live score 1.00"));
        let off = HealthSnapshot::disabled(vec![]).report();
        assert!(off.contains("health: disabled"));
    }
}
