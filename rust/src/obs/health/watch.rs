//! The watcher: a fixed-capacity snapshot ring, anomaly detectors, and
//! firing/resolved hysteresis.
//!
//! [`HealthState::observe`] is a *pure* state transition: feed it a
//! timestamp and a [`RegistrySnapshot`] and it updates the time-series
//! ring, judges every detector, and returns/emits only the
//! *transitions* (fire after `fire_after` consecutive breaches, resolve
//! after `resolve_after` consecutive clears). The serving tier's
//! watcher thread is a thin loop around it — which is also why every
//! detector is unit-testable with synthetic snapshots and no clock.
//!
//! Detectors:
//! * **p99 regression** — cumulative `serve.latency` p99 vs. a rolling
//!   EWMA baseline (the baseline keeps adapting, so a step change fires
//!   and then self-resolves once the new normal is learned);
//! * **admission saturation** — `serve.inflight` vs.
//!   `serve.inflight_capacity` gauges;
//! * **cache-hit collapse** — windowed `engine.cache_hit` /
//!   `engine.cache_miss` deltas;
//! * **device outliers** — per-device EWMA latency vs. the live-peer
//!   median, and windowed retryable-error rates;
//! * **SLO burn** — [`slo::evaluate`] per configured tenant, firing
//!   only when the short *and* long windows both burn ≥ 1.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;

use super::alert::{Alert, AlertKind, AlertSeverity, AlertSink, AlertState};
use super::slo::{self, SloStatus};
use super::{DeviceHealth, HealthConfig, HealthSnapshot};
use crate::obs::RegistrySnapshot;

/// Watcher cadence and detector thresholds. Defaults are tuned for the
/// bench/test fixtures (tens of milliseconds end to end); production
/// deployments raise `interval_ms` and the windows together.
#[derive(Clone, Debug)]
pub struct WatchConfig {
    /// Sampling interval of the background watcher thread, ms.
    pub interval_ms: u64,
    /// Ring capacity (snapshots retained).
    pub history: usize,
    /// Short burn/delta window, in snapshots.
    pub short_window: usize,
    /// Long burn window, in snapshots.
    pub long_window: usize,
    /// Consecutive breaching snapshots before an alert fires.
    pub fire_after: u32,
    /// Consecutive clear snapshots before a firing alert resolves.
    pub resolve_after: u32,
    /// p99 regression threshold: fire when p99 > factor × EWMA baseline.
    pub p99_factor: f64,
    /// EWMA smoothing for the p99 baseline (weight of the newest point).
    pub ewma_alpha: f64,
    /// Admission saturation threshold (fraction of window capacity).
    pub saturation: f64,
    /// Cache-hit collapse floor (windowed hit rate below this fires).
    pub cache_hit_floor: f64,
    /// Minimum windowed activity (events) before a rate is judged.
    pub min_activity: u64,
    /// Device latency-outlier threshold (× live-peer median EWMA).
    pub device_factor: f64,
    /// Device windowed retryable-error-rate threshold.
    pub device_error_rate: f64,
}

impl Default for WatchConfig {
    fn default() -> Self {
        WatchConfig {
            interval_ms: 25,
            history: 256,
            short_window: 5,
            long_window: 60,
            fire_after: 3,
            resolve_after: 5,
            p99_factor: 3.0,
            ewma_alpha: 0.2,
            saturation: 0.9,
            cache_hit_floor: 0.5,
            min_activity: 8,
            device_factor: 8.0,
            device_error_rate: 0.5,
        }
    }
}

/// One entry of the watcher's time-series ring.
#[derive(Clone, Debug)]
pub struct SnapshotPoint {
    /// Watcher-epoch timestamp, nanoseconds.
    pub t_ns: u64,
    /// The sampled registry state.
    pub snap: RegistrySnapshot,
}

#[derive(Clone, Copy, Debug, Default)]
struct DetectorState {
    breach_streak: u32,
    ok_streak: u32,
    firing: bool,
}

struct Judgment {
    key: String,
    breach: bool,
    kind: AlertKind,
    severity: AlertSeverity,
    subject: String,
    value: f64,
    threshold: f64,
    message: String,
}

/// The watcher's whole mutable state: ring + detector streaks + active
/// alerts + sinks. The serving tier wraps one of these in a mutex; unit
/// tests drive it directly.
pub struct HealthState {
    cfg: HealthConfig,
    ring: VecDeque<SnapshotPoint>,
    ewma_p99: f64,
    detectors: BTreeMap<String, DetectorState>,
    active: BTreeMap<String, Alert>,
    sinks: Vec<Box<dyn AlertSink>>,
    snapshots_seen: u64,
    alerts_fired: u64,
}

impl fmt::Debug for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("HealthState")
            .field("snapshots_seen", &self.snapshots_seen)
            .field("alerts_fired", &self.alerts_fired)
            .field("active", &self.active.len())
            .field("sinks", &self.sinks.len())
            .finish()
    }
}

impl HealthState {
    /// Fresh state for `cfg` (no snapshots, no alerts, no sinks).
    pub fn new(cfg: HealthConfig) -> Self {
        HealthState {
            cfg,
            ring: VecDeque::new(),
            ewma_p99: 0.0,
            detectors: BTreeMap::new(),
            active: BTreeMap::new(),
            sinks: Vec::new(),
            snapshots_seen: 0,
            alerts_fired: 0,
        }
    }

    /// Attach a sink; every future transition is delivered to it.
    pub fn add_sink(&mut self, sink: Box<dyn AlertSink>) {
        self.sinks.push(sink);
    }

    /// Snapshots observed so far.
    pub fn snapshots_seen(&self) -> u64 {
        self.snapshots_seen
    }

    /// Firing transitions emitted so far (resolutions not counted).
    pub fn alerts_fired(&self) -> u64 {
        self.alerts_fired
    }

    /// Currently-firing alerts, in stable key order.
    pub fn active_alerts(&self) -> Vec<Alert> {
        self.active.values().cloned().collect()
    }

    /// Ingest one sampled snapshot: extend the ring, judge every
    /// detector, apply hysteresis, emit transitions to the sinks, and
    /// return them (callers without sinks still see what changed).
    pub fn observe(&mut self, t_ns: u64, snap: RegistrySnapshot) -> Vec<Alert> {
        self.ring.push_back(SnapshotPoint { t_ns, snap });
        let cap = self.cfg.watch.history.max(2);
        while self.ring.len() > cap {
            self.ring.pop_front();
        }
        self.snapshots_seen += 1;
        if self.ring.len() < 2 {
            return Vec::new(); // windowed judgments need a base point
        }
        let judgments = self.judge();
        if let Some((p99, activity)) = self.latency_signal() {
            if activity >= self.cfg.watch.min_activity && p99 > 0 {
                // baseline adapts every active snapshot — step changes
                // fire, then self-resolve once the new normal is learned
                let a = self.cfg.watch.ewma_alpha;
                self.ewma_p99 = if self.ewma_p99 == 0.0 {
                    p99 as f64
                } else {
                    a * p99 as f64 + (1.0 - a) * self.ewma_p99
                };
            }
        }
        let transitions = self.apply(t_ns, judgments);
        for alert in &transitions {
            for sink in &self.sinks {
                sink.emit(alert);
            }
        }
        transitions
    }

    /// Evaluate every configured SLO against the current ring.
    pub fn slo_statuses(&self) -> Vec<SloStatus> {
        let empty = RegistrySnapshot::new();
        let newest = self.ring.back().map(|p| &p.snap).unwrap_or(&empty);
        let short = self.base(self.cfg.watch.short_window).unwrap_or(&empty);
        let long = self.base(self.cfg.watch.long_window).unwrap_or(&empty);
        self.cfg.slos.iter().map(|def| slo::evaluate(def, newest, short, long)).collect()
    }

    /// Assemble the wire-facing health snapshot (the caller supplies
    /// per-device health from the farm — the watcher only sees the
    /// registry).
    pub fn snapshot(&self, devices: Vec<DeviceHealth>) -> HealthSnapshot {
        HealthSnapshot {
            enabled: true,
            snapshots: self.snapshots_seen,
            alerts_total: self.alerts_fired,
            slos: self.slo_statuses(),
            alerts: self.active_alerts(),
            devices,
        }
    }

    fn newest(&self) -> &RegistrySnapshot {
        // observe() guarantees non-empty before judging
        &self.ring.back().expect("ring non-empty").snap
    }

    /// Base snapshot `window` points back (clamped to ring length).
    fn base(&self, window: usize) -> Option<&RegistrySnapshot> {
        if self.ring.len() < 2 {
            return None;
        }
        let k = window.max(1).min(self.ring.len() - 1);
        Some(&self.ring[self.ring.len() - 1 - k].snap)
    }

    /// (cumulative p99 ns, windowed completed-request activity) of the
    /// serving latency histogram.
    fn latency_signal(&self) -> Option<(u64, u64)> {
        let newest = self.ring.back()?;
        let h = newest.snap.histogram("serve.latency")?;
        let base_count = self
            .base(self.cfg.watch.short_window)
            .and_then(|b| b.histogram("serve.latency"))
            .map(|b| b.count)
            .unwrap_or(0);
        Some((h.p99_ns, h.count.saturating_sub(base_count)))
    }

    fn judge(&self) -> Vec<Judgment> {
        let w = &self.cfg.watch;
        let newest = self.newest();
        let empty = RegistrySnapshot::new();
        let short = self.base(w.short_window).unwrap_or(&empty);
        let long = self.base(w.long_window).unwrap_or(&empty);
        let delta = |name: &str| {
            newest.counter(name).unwrap_or(0).saturating_sub(short.counter(name).unwrap_or(0))
        };
        let mut out = Vec::new();

        // p99 regression vs. the rolling EWMA baseline
        if let Some((p99, activity)) = self.latency_signal() {
            let ratio = if self.ewma_p99 > 0.0 { p99 as f64 / self.ewma_p99 } else { 0.0 };
            let breach =
                activity >= w.min_activity && self.ewma_p99 > 0.0 && ratio > w.p99_factor;
            out.push(Judgment {
                key: "p99".to_string(),
                breach,
                kind: AlertKind::P99Regression,
                severity: AlertSeverity::Warning,
                subject: "serve".to_string(),
                value: ratio,
                threshold: w.p99_factor,
                message: format!(
                    "serve.latency p99 {p99}ns vs EWMA baseline {:.0}ns",
                    self.ewma_p99
                ),
            });
        }

        // admission-window saturation
        let cap = newest.gauge("serve.inflight_capacity").unwrap_or(0);
        if cap > 0 {
            let inflight = newest.gauge("serve.inflight").unwrap_or(0);
            let frac = inflight as f64 / cap as f64;
            out.push(Judgment {
                key: "admission".to_string(),
                breach: frac >= w.saturation,
                kind: AlertKind::AdmissionSaturation,
                severity: AlertSeverity::Warning,
                subject: "serve".to_string(),
                value: frac,
                threshold: w.saturation,
                message: format!("{inflight}/{cap} admission slots in use"),
            });
        }

        // program-cache hit-rate collapse (windowed)
        let hits = delta("engine.cache_hit");
        let misses = delta("engine.cache_miss");
        if hits + misses >= w.min_activity {
            let rate = hits as f64 / (hits + misses) as f64;
            out.push(Judgment {
                key: "cache".to_string(),
                breach: rate < w.cache_hit_floor,
                kind: AlertKind::CacheHitCollapse,
                severity: AlertSeverity::Warning,
                subject: "engine".to_string(),
                value: rate,
                threshold: w.cache_hit_floor,
                message: format!("windowed hit rate {rate:.2} ({hits} hits / {misses} misses)"),
            });
        }

        // per-device latency/error outliers
        let devices = device_indices(newest);
        let mut live_ewmas: Vec<u64> = devices
            .iter()
            .filter(|d| newest.gauge(&format!("farm.device{d}.live")) == Some(1))
            .filter_map(|d| newest.gauge(&format!("farm.device{d}.ewma_ns")))
            .filter(|&e| e > 0)
            .collect();
        live_ewmas.sort_unstable();
        // lower-median, like FgpFarm::device_health: in a two-device
        // farm the slow member is judged against the fast one, not
        // against itself
        let median =
            if live_ewmas.is_empty() { 0 } else { live_ewmas[(live_ewmas.len() - 1) / 2] };
        for d in devices {
            let subject = format!("farm.device{d}");
            if newest.gauge(&format!("{subject}.live")) != Some(1) {
                continue; // dead devices are the farm's problem, not an outlier
            }
            let ewma = newest.gauge(&format!("{subject}.ewma_ns")).unwrap_or(0);
            let dreq = delta(&format!("{subject}.requests"));
            let derr = delta(&format!("{subject}.errors"));
            let lat_ratio = if median > 0 { ewma as f64 / median as f64 } else { 0.0 };
            let err_rate = if dreq + derr >= w.min_activity {
                derr as f64 / (dreq + derr) as f64
            } else {
                0.0
            };
            let lat_breach = lat_ratio > w.device_factor;
            let err_breach = err_rate > w.device_error_rate;
            let (value, threshold) = if err_breach && !lat_breach {
                (err_rate, w.device_error_rate)
            } else {
                (lat_ratio, w.device_factor)
            };
            out.push(Judgment {
                key: subject.clone(),
                breach: lat_breach || err_breach,
                kind: AlertKind::DeviceOutlier,
                severity: AlertSeverity::Warning,
                subject: subject.clone(),
                value,
                threshold,
                message: format!(
                    "ewma {ewma}ns ({lat_ratio:.1}× live median {median}ns), \
                     windowed error rate {err_rate:.2}"
                ),
            });
        }

        // per-tenant SLO burn (short AND long window)
        for def in &self.cfg.slos {
            let st = slo::evaluate(def, newest, short, long);
            out.push(Judgment {
                key: format!("slo.{}", def.tenant),
                breach: st.burn_short >= 1.0 && st.burn_long >= 1.0,
                kind: AlertKind::SloBurn,
                severity: AlertSeverity::Critical,
                subject: format!("tenant.{}", def.tenant),
                value: st.burn_short,
                threshold: 1.0,
                message: format!(
                    "burn {:.2}×/{:.2}× (short/long) against budget {}",
                    st.burn_short, st.burn_long, def.error_budget
                ),
            });
        }
        out
    }

    fn apply(&mut self, t_ns: u64, judgments: Vec<Judgment>) -> Vec<Alert> {
        let (fire_after, resolve_after) =
            (self.cfg.watch.fire_after.max(1), self.cfg.watch.resolve_after.max(1));
        let mut out = Vec::new();
        for j in judgments {
            let st = self.detectors.entry(j.key.clone()).or_default();
            if j.breach {
                st.breach_streak += 1;
                st.ok_streak = 0;
            } else {
                st.ok_streak += 1;
                st.breach_streak = 0;
            }
            let alert = |state: AlertState| Alert {
                kind: j.kind,
                state,
                severity: j.severity,
                subject: j.subject.clone(),
                value: j.value,
                threshold: j.threshold,
                t_ns,
                message: j.message.clone(),
            };
            if !st.firing && st.breach_streak >= fire_after {
                st.firing = true;
                let a = alert(AlertState::Firing);
                self.active.insert(j.key, a.clone());
                self.alerts_fired += 1;
                out.push(a);
            } else if st.firing && st.ok_streak >= resolve_after {
                st.firing = false;
                self.active.remove(&j.key);
                out.push(alert(AlertState::Resolved));
            }
        }
        out
    }
}

/// Device indices present in a snapshot (from `farm.device<i>.ewma_ns`
/// gauges, which the serving tier publishes for every slot).
fn device_indices(snap: &RegistrySnapshot) -> Vec<u32> {
    let mut out = Vec::new();
    for g in &snap.gauges {
        if let Some(rest) = g.name.strip_prefix("farm.device") {
            if let Some(idx) = rest.strip_suffix(".ewma_ns") {
                if let Ok(d) = idx.parse::<u32>() {
                    out.push(d);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::health::VecSink;
    use crate::obs::HistSummary;
    use std::sync::Arc;

    fn cfg() -> HealthConfig {
        let mut c = HealthConfig::on();
        c.watch.fire_after = 2;
        c.watch.resolve_after = 2;
        c.watch.short_window = 2;
        c.watch.min_activity = 4;
        c
    }

    fn lat_snap(count: u64, p99_ns: u64) -> RegistrySnapshot {
        let mut s = RegistrySnapshot::new();
        s.histograms.push(HistSummary {
            name: "serve.latency".into(),
            count,
            mean_ns: p99_ns / 2,
            p50_ns: p99_ns / 2,
            p95_ns: p99_ns,
            p99_ns,
        });
        s
    }

    #[test]
    fn p99_regression_fires_after_streak_and_resolves() {
        let mut hs = HealthState::new(cfg());
        let sink = Arc::new(VecSink::new());
        hs.add_sink(Box::new(Arc::clone(&sink)));
        let mut t = 0u64;
        let mut count = 0u64;
        let mut feed = |hs: &mut HealthState, p99: u64| {
            t += 1_000_000;
            count += 10;
            hs.observe(t, lat_snap(count, p99))
        };
        for _ in 0..6 {
            assert!(feed(&mut hs, 1_000).is_empty(), "stable baseline must not alert");
        }
        // 10× step: breach streak 1, then fire on the 2nd
        assert!(feed(&mut hs, 10_000).is_empty());
        let fired = feed(&mut hs, 10_000);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::P99Regression);
        assert_eq!(fired[0].state, AlertState::Firing);
        assert_eq!(hs.active_alerts().len(), 1);
        assert_eq!(hs.alerts_fired(), 1);
        // baseline adapts to the new normal → eventually resolves
        let mut resolved = false;
        for _ in 0..40 {
            for a in feed(&mut hs, 10_000) {
                resolved |= a.state == AlertState::Resolved;
            }
        }
        assert!(resolved, "EWMA baseline must learn the new normal");
        assert!(hs.active_alerts().is_empty());
        assert!(sink.len() >= 2, "sink saw both transitions");
    }

    #[test]
    fn admission_saturation_uses_gauges() {
        let mut hs = HealthState::new(cfg());
        let snap = |inflight: u64| {
            let mut s = RegistrySnapshot::new();
            s.push_gauge("serve.inflight", inflight);
            s.push_gauge("serve.inflight_capacity", 10);
            s
        };
        hs.observe(1, snap(2));
        let mut fired = Vec::new();
        for i in 0..3 {
            fired.extend(hs.observe(2 + i, snap(10)));
        }
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::AdmissionSaturation);
        assert!(fired[0].value >= 0.9);
    }

    #[test]
    fn cache_collapse_needs_min_activity() {
        let mut hs = HealthState::new(cfg());
        let snap = |hits: u64, misses: u64| {
            let mut s = RegistrySnapshot::new();
            s.push_counter("engine.cache_hit", hits);
            s.push_counter("engine.cache_miss", misses);
            s
        };
        hs.observe(1, snap(0, 0));
        // only 2 windowed events < min_activity 4: never judged
        hs.observe(2, snap(1, 1));
        assert!(hs.observe(3, snap(2, 2)).is_empty() || hs.active_alerts().is_empty());
        // heavy miss traffic: fires
        let mut fired = Vec::new();
        fired.extend(hs.observe(4, snap(3, 20)));
        fired.extend(hs.observe(5, snap(4, 40)));
        fired.extend(hs.observe(6, snap(5, 60)));
        assert!(fired.iter().any(|a| a.kind == AlertKind::CacheHitCollapse));
    }

    #[test]
    fn device_outlier_judges_against_live_median() {
        let mut hs = HealthState::new(cfg());
        let snap = |slow_ns: u64| {
            let mut s = RegistrySnapshot::new();
            for d in 0..3u32 {
                s.push_gauge(&format!("farm.device{d}.live"), 1);
                let ewma = if d == 2 { slow_ns } else { 1_000 };
                s.push_gauge(&format!("farm.device{d}.ewma_ns"), ewma);
                s.push_counter(&format!("farm.device{d}.requests"), 100);
                s.push_counter(&format!("farm.device{d}.errors"), 0);
            }
            s.sort();
            s
        };
        hs.observe(1, snap(1_000));
        let mut fired = Vec::new();
        for i in 0..3 {
            fired.extend(hs.observe(2 + i, snap(20_000))); // 20× the median
        }
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::DeviceOutlier);
        assert_eq!(fired[0].subject, "farm.device2");
    }

    #[test]
    fn slo_burn_needs_both_windows_and_is_critical() {
        let mut c = cfg();
        c.watch.long_window = 4;
        c.slos.push(slo::SloDef::new("acme", 0, 0.01));
        let mut hs = HealthState::new(c);
        let snap = |req: u64, rej: u64| {
            let mut s = RegistrySnapshot::new();
            s.push_counter("tenant.acme.requests", req);
            s.push_counter("tenant.acme.rejected_quota", rej);
            s.push_counter("tenant.acme.rejected_busy", 0);
            s
        };
        hs.observe(1, snap(0, 0));
        let mut fired = Vec::new();
        let mut req = 0;
        let mut rej = 0;
        for i in 0..6 {
            req += 100;
            rej += 10; // 10% rejections against a 1% budget on every window
            fired.extend(hs.observe(2 + i, snap(req, rej)));
        }
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::SloBurn);
        assert_eq!(fired[0].severity, AlertSeverity::Critical);
        assert_eq!(fired[0].subject, "tenant.acme");
        let statuses = hs.slo_statuses();
        assert_eq!(statuses.len(), 1);
        assert!(!statuses[0].healthy);
    }

    #[test]
    fn clean_traffic_never_alerts_and_snapshot_assembles() {
        let mut hs = HealthState::new(cfg());
        for i in 0..50u64 {
            let mut s = lat_snap(10 * (i + 1), 1_000 + (i % 7) * 10); // mild jitter
            s.push_gauge("serve.inflight", 1);
            s.push_gauge("serve.inflight_capacity", 10);
            s.push_counter("engine.cache_hit", 100 * (i + 1));
            s.push_counter("engine.cache_miss", 1);
            s.sort();
            assert!(hs.observe(i * 1_000_000, s).is_empty(), "snapshot {i}");
        }
        assert_eq!(hs.alerts_fired(), 0);
        let snap = hs.snapshot(Vec::new());
        assert!(snap.enabled);
        assert_eq!(snap.snapshots, 50);
        assert_eq!(snap.alerts_total, 0);
        assert!(snap.alerts.is_empty());
    }

    #[test]
    fn ring_is_capacity_bounded() {
        let mut c = cfg();
        c.watch.history = 8;
        let mut hs = HealthState::new(c);
        for i in 0..100u64 {
            hs.observe(i, RegistrySnapshot::new());
        }
        assert_eq!(hs.snapshots_seen(), 100);
        assert!(hs.ring.len() <= 8);
    }
}
