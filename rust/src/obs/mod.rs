//! S11 — Observability: cross-layer tracing, a unified metrics
//! registry, and exportable execution timelines.
//!
//! The paper's FGP exposes only a status port (§IV: the FSM's four
//! states are all the silicon reports); the simulator can afford what
//! the silicon cannot — a full, *correlated* picture of every update.
//! This module is that picture, std-only like the rest of the crate:
//!
//! * [`span`] — [`TraceContext`] request identity (minted at the edge,
//!   carried bit-exactly through the wire codec, propagated
//!   serve → admission → engine room → farm device → engine run) plus a
//!   lock-free [`SpanRing`] recorder with monotonic timestamps, all
//!   behind a [`Telemetry`] handle whose [`TelemetryConfig`] off-switch
//!   reduces every hot-path hook to one branch;
//! * [`metrics`] — [`MetricsRegistry`], the named counter / gauge /
//!   histogram table that absorbs the serving tier's
//!   [`Metrics`](crate::coordinator::Metrics), the session program-cache
//!   hit/miss counters, coalescer batch stats and per-opcode profiler
//!   cycle totals behind one wire-exportable [`RegistrySnapshot`];
//! * [`export`] — [`chrome_trace`] (Chrome/Perfetto trace-event JSON;
//!   device cycle spans are rescaled onto the wall-clock timeline at
//!   the paper's 130 MHz so a compiled program's MMA/FAD phases render
//!   *inside* the serving span that dispatched them),
//!   [`flame_summary`] (a human-readable per-request tree) and
//!   [`prometheus_text`] (registry snapshots in the Prometheus text
//!   exposition format);
//! * [`health`] — the operational-intelligence layer on top of all of
//!   it: per-tenant SLO burn rates, the background watcher's anomaly
//!   detectors with firing/resolved hysteresis, structured
//!   [`Alert`](health::Alert) sinks, and the per-device
//!   [`device_score`](health::device_score) behind health-aware
//!   routing.
//!
//! The pinned contract (ARCHITECTURE.md invariant 7): telemetry off ⇒
//! bitwise-identical results to an uninstrumented build, with the
//! disabled-path overhead regression-gated by
//! `rust/benches/obs_overhead.rs` → `BENCH_obs.json`; the health layer
//! extends it — health off ⇒ no watcher thread and no clock reads,
//! gated by `rust/benches/health_slo.rs` → `BENCH_health.json`.

pub mod export;
pub mod health;
pub mod metrics;
pub mod span;

pub use export::{chrome_trace, flame_summary, prometheus_text};
pub use health::{
    Alert, AlertKind, AlertSeverity, AlertSink, AlertState, DeviceHealth, HealthConfig,
    HealthSnapshot, HealthState, SloDef, SloStatus, WatchConfig,
};
pub use metrics::{CounterSample, HistSummary, MetricsRegistry, RegistrySnapshot};
pub use span::{SpanRecord, SpanRing, Telemetry, TelemetryConfig, TraceContext};
