//! Nonlinear measurement factors: `z = h(x) + v`.
//!
//! The device's node vocabulary is linear-Gaussian; a nonlinear factor
//! carries the measurement function `h`, the measurement `z`, and the
//! observation noise, and is turned into a linear compound-observation
//! section by a [`super::Linearizer`]. Measurements occupy the first
//! `m ≤ n` components of the device's `n`-dim state; the remaining rows
//! of the linearized state matrix are zero, so they observe pure noise
//! and add no information (the same rank-deficiency trick
//! `apps/toa` and the GBP unary lowering already rely on).

use std::fmt;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;

/// Measurement function on the real state vector (length `n` in, `m` out).
pub type HFn = Arc<dyn Fn(&[f64]) -> Vec<f64> + Send + Sync>;

/// Analytic Jacobian: `m` rows of `n` partial derivatives.
pub type JFn = Arc<dyn Fn(&[f64]) -> Vec<Vec<f64>> + Send + Sync>;

/// Two-argument measurement function `h(x_from, x_to)` for relative
/// (pairwise) factors such as inter-pose ranges.
pub type H2Fn = Arc<dyn Fn(&[f64], &[f64]) -> Vec<f64> + Send + Sync>;

/// Finite-difference step for numeric Jacobians (relative to |x_i|).
const FD_STEP: f64 = 1e-6;

/// A nonlinear observation of one `n`-dim variable: `z = h(x) + v`,
/// `v ~ N(0, noise_var · I_m)`, with `h` acting on the **real part** of
/// the state (the nonlinear workloads this subsystem serves — ranging,
/// bearing — are real-valued; complex states embed them component-wise).
#[derive(Clone)]
pub struct NonlinearFactor {
    /// State dimension (must match the device size).
    pub n: usize,
    /// Measurement dimension (`m ≤ n`, occupies components `0..m`).
    pub m: usize,
    /// Measurement function.
    pub h: HFn,
    /// Analytic Jacobian; `None` falls back to central differences.
    pub jac: Option<JFn>,
    /// Measured value, length `m`.
    pub z: Vec<f64>,
    /// Observation noise variance per measurement component.
    pub noise_var: f64,
}

impl fmt::Debug for NonlinearFactor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("NonlinearFactor")
            .field("n", &self.n)
            .field("m", &self.m)
            .field("jac", &self.jac.is_some().then_some("analytic"))
            .field("z", &self.z)
            .field("noise_var", &self.noise_var)
            .finish()
    }
}

impl NonlinearFactor {
    /// A factor `z = h(x) + v` (shape-checked; `m` outputs from `n` states).
    pub fn new(n: usize, m: usize, h: HFn, z: Vec<f64>, noise_var: f64) -> Result<Self> {
        if m == 0 || m > n {
            bail!("measurement dimension m={m} must satisfy 1 <= m <= n={n}");
        }
        if z.len() != m {
            bail!("measurement has {} components but m={m}", z.len());
        }
        if !(noise_var > 0.0) {
            bail!("noise variance must be positive, got {noise_var}");
        }
        Ok(NonlinearFactor { n, m, h, jac: None, z, noise_var })
    }

    /// Attach an analytic Jacobian (`m` rows × `n` cols).
    pub fn with_jacobian(mut self, jac: JFn) -> Self {
        self.jac = Some(jac);
        self
    }

    /// Evaluate `h` at the (real) state `x`, checking dimensions.
    pub fn eval(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.n {
            bail!("state has {} components but n={}", x.len(), self.n);
        }
        let y = (self.h)(x);
        if y.len() != self.m {
            bail!("h returned {} components but m={}", y.len(), self.m);
        }
        Ok(y)
    }

    /// Jacobian of `h` at `x`: analytic if supplied, central differences
    /// otherwise. `m` rows × `n` cols.
    pub fn jacobian(&self, x: &[f64]) -> Result<Vec<Vec<f64>>> {
        if let Some(j) = &self.jac {
            let rows = j(x);
            if rows.len() != self.m || rows.iter().any(|r| r.len() != self.n) {
                bail!(
                    "analytic Jacobian must be {}x{}, got {}x{}",
                    self.m,
                    self.n,
                    rows.len(),
                    rows.first().map_or(0, |r| r.len())
                );
            }
            return Ok(rows);
        }
        let mut rows = vec![vec![0.0; self.n]; self.m];
        let mut xp = x.to_vec();
        let mut xm = x.to_vec();
        for i in 0..self.n {
            let step = FD_STEP * (1.0 + x[i].abs());
            xp[i] = x[i] + step;
            xm[i] = x[i] - step;
            let hp = self.eval(&xp).context("numeric Jacobian (forward point)")?;
            let hm = self.eval(&xm).context("numeric Jacobian (backward point)")?;
            for (r, row) in rows.iter_mut().enumerate() {
                row[i] = (hp[r] - hm[r]) / (2.0 * step);
            }
            xp[i] = x[i];
            xm[i] = x[i];
        }
        Ok(rows)
    }
}

/// A nonlinear relative measurement between two variables:
/// `z = h(x_from, x_to) + v`, `v ~ N(0, noise_var · I_m)` — the GBP
/// pairwise analogue of [`NonlinearFactor`] (inter-pose ranges,
/// relative bearings). Linearized per endpoint by any
/// [`super::Linearizer`] via single-argument adapters that hold the
/// other endpoint at its current belief mean.
#[derive(Clone)]
pub struct PairwiseNonlinear {
    /// Dimension of each endpoint's state.
    pub n: usize,
    /// Measurement dimension.
    pub m: usize,
    /// The measurement function `h(x_from, x_to)`.
    pub h: H2Fn,
    /// Measured value.
    pub z: Vec<f64>,
    /// Measurement noise variance.
    pub noise_var: f64,
}

impl fmt::Debug for PairwiseNonlinear {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PairwiseNonlinear")
            .field("n", &self.n)
            .field("m", &self.m)
            .field("z", &self.z)
            .field("noise_var", &self.noise_var)
            .finish()
    }
}

impl PairwiseNonlinear {
    /// A pairwise factor `z = h(x_from, x_to) + v` (shape-checked).
    pub fn new(n: usize, m: usize, h: H2Fn, z: Vec<f64>, noise_var: f64) -> Result<Self> {
        if m == 0 || m > n {
            bail!("measurement dimension m={m} must satisfy 1 <= m <= n={n}");
        }
        if z.len() != m {
            bail!("measurement has {} components but m={m}", z.len());
        }
        if !(noise_var > 0.0) {
            bail!("noise variance must be positive, got {noise_var}");
        }
        Ok(PairwiseNonlinear { n, m, h, z, noise_var })
    }

    /// Evaluate `h` at the (real) endpoint states.
    pub fn eval(&self, x_from: &[f64], x_to: &[f64]) -> Result<Vec<f64>> {
        if x_from.len() != self.n || x_to.len() != self.n {
            bail!("endpoint states must both have n={} components", self.n);
        }
        let y = (self.h)(x_from, x_to);
        if y.len() != self.m {
            bail!("h returned {} components but m={}", y.len(), self.m);
        }
        Ok(y)
    }

    /// Single-argument adapter over `x_from` with `x_to` frozen, so any
    /// [`super::Linearizer`] (Jacobian or sigma-point) applies per
    /// endpoint.
    pub fn adapter_from(&self, x_to: &[f64]) -> Result<NonlinearFactor> {
        let h = Arc::clone(&self.h);
        let frozen = x_to.to_vec();
        NonlinearFactor::new(
            self.n,
            self.m,
            Arc::new(move |x: &[f64]| h(x, &frozen)),
            self.z.clone(),
            self.noise_var,
        )
    }

    /// Single-argument adapter over `x_to` with `x_from` frozen.
    pub fn adapter_to(&self, x_from: &[f64]) -> Result<NonlinearFactor> {
        let h = Arc::clone(&self.h);
        let frozen = x_from.to_vec();
        NonlinearFactor::new(
            self.n,
            self.m,
            Arc::new(move |x: &[f64]| h(&frozen, x)),
            self.z.clone(),
            self.noise_var,
        )
    }
}

/// Embed an `m×n` real Jacobian block into the device's `n×n` state
/// matrix (zero rows below observe pure noise).
pub fn pad_matrix(rows: &[Vec<f64>], n: usize) -> CMatrix {
    let mut a = CMatrix::zeros(n, n);
    for (i, row) in rows.iter().enumerate() {
        for (j, v) in row.iter().enumerate() {
            a[(i, j)] = c64::new(*v, 0.0);
        }
    }
    a
}

/// Embed `m` real measurement components into an `n`-dim mean vector.
pub fn pad_vector(vals: &[f64], n: usize) -> Vec<c64> {
    let mut v = vec![c64::ZERO; n];
    for (i, x) in vals.iter().enumerate() {
        v[i] = c64::new(*x, 0.0);
    }
    v
}

/// Real part of a message mean (the state the nonlinear `h` acts on).
pub fn real_mean(msg: &GaussMessage) -> Vec<f64> {
    msg.mean.iter().map(|z| z.re).collect()
}
