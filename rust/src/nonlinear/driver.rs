//! Iterated relinearization: nonlinear estimation as cache-hitting sweeps.
//!
//! A [`NonlinearProblem`] is a Gaussian prior (optionally pushed through
//! a linear motion model) refined by nonlinear measurement factors. The
//! [`IteratedRelinearization`] driver sweeps
//!
//! ```text
//!   re-linearize (at the current belief) → run the sweep → update belief
//! ```
//!
//! to a Gauss–Newton-style fixed point (Petersen et al. 2019): every
//! round starts from the **same** prior and only the linearization point
//! moves, so the fixed point coincides with the MAP/Gauss–Newton
//! solution of the nonlinear problem (pinned against [`gauss_newton`] by
//! `rust/tests/property_nonlinear.rs`).
//!
//! Each round's sweep is a [`RelinSweep`] workload with a **fixed graph
//! shape** — only the streamed state matrices and pseudo-observations
//! change between rounds — so every round after the first is a program-
//! cache hit on the [`Session`] (the same property `apps/toa` exploited
//! with its private loop, now available to every nonlinear workload).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::WorkloadRequest;
use crate::engine::{bind_streamed, preload_id, Execution, Session, Workload};
use crate::gbp::RoundExecutor;
use crate::gmp::message::GaussMessage;
use crate::gmp::{nodes, FactorGraph, MsgId, NodeKind, Schedule};

use super::factor::{real_mean, NonlinearFactor};
use super::linearize::{Linearization, Linearizer};

/// A nonlinear estimation problem over one `n`-dim state.
#[derive(Clone, Debug)]
pub struct NonlinearProblem {
    /// State dimension (must match the device size).
    pub n: usize,
    /// Gaussian prior on the state.
    pub prior: GaussMessage,
    /// Optional linear motion prelude applied to the prior inside the
    /// sweep graph: `x ← F x + w`, `w ~ noise` (mean = control input,
    /// covariance = process noise). This is how a tracking step folds
    /// predict + update into **one** fixed-shape workload.
    pub motion: Option<(crate::gmp::matrix::CMatrix, GaussMessage)>,
    /// Nonlinear measurement factors, one compound section each.
    pub factors: Vec<NonlinearFactor>,
}

impl NonlinearProblem {
    /// Prior as seen by the measurement sections: pushed through the
    /// motion prelude when one is present (the linearization point must
    /// live where the nonlinear sections actually observe the state).
    pub fn predicted_prior(&self) -> GaussMessage {
        match &self.motion {
            None => self.prior.clone(),
            Some((f, noise)) => nodes::add(&nodes::multiply(&self.prior, f), noise),
        }
    }

    fn check(&self) -> Result<()> {
        if self.prior.dim() != self.n {
            bail!("prior has dim {} but the problem is n={}", self.prior.dim(), self.n);
        }
        if self.factors.is_empty() {
            bail!("a nonlinear problem needs at least one measurement factor");
        }
        for (i, f) in self.factors.iter().enumerate() {
            if f.n != self.n {
                bail!("factor {i} has n={} but the problem is n={}", f.n, self.n);
            }
        }
        if let Some((f, noise)) = &self.motion {
            if f.rows != self.n || f.cols != self.n || noise.dim() != self.n {
                bail!("motion model shapes must be n={}", self.n);
            }
        }
        Ok(())
    }
}

/// One relinearization round: the problem's factors linearized at a
/// fixed belief, lowered as a compound-observation chain (with the
/// optional multiplier/adder motion prelude). The graph **shape** is a
/// function of the factor count and motion flag only, never of the
/// linearization point — the cache-hit invariant.
#[derive(Clone, Debug)]
pub struct RelinSweep<'p> {
    /// The problem this sweep linearizes.
    pub problem: &'p NonlinearProblem,
    /// Per-factor linearizations, in factor order.
    pub sections: Vec<Linearization>,
}

impl<'p> RelinSweep<'p> {
    /// Linearize every factor of `problem` at `at` (the predicted prior
    /// on the first round, the previous round's posterior afterwards).
    pub fn linearize_at(
        problem: &'p NonlinearProblem,
        at: &GaussMessage,
        linearizer: &dyn Linearizer,
    ) -> Result<Self> {
        problem.check()?;
        let sections = problem
            .factors
            .iter()
            .enumerate()
            .map(|(i, f)| {
                linearizer
                    .linearize(f, at)
                    .with_context(|| format!("linearizing factor {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RelinSweep { problem, sections })
    }

    /// The sweep as a raw serving request (farm / coordinator path).
    pub fn to_request(&self) -> Result<WorkloadRequest> {
        WorkloadRequest::from_workload(self)
    }
}

impl Workload for RelinSweep<'_> {
    type Outcome = GaussMessage;

    fn name(&self) -> &str {
        "relin_sweep"
    }

    fn n(&self) -> usize {
        self.problem.n
    }

    /// Without a motion prelude this is exactly the `rls_chain` shape
    /// (one CN section per factor, streamed states/observations); with
    /// one, a multiplier + adder precede the chain.
    fn model(&self) -> Result<(FactorGraph, Schedule)> {
        let n = self.n();
        let mut g = FactorGraph::new();
        let a_list: Vec<_> = self.sections.iter().map(|s| s.a.clone()).collect();
        match &self.problem.motion {
            None => {
                g.rls_chain(n, &a_list);
            }
            Some((f, _)) => {
                // motion prelude, then the same sectioned chain body
                // rls_chain uses (one shared builder, one convention)
                let prior = g.add_input_edge(n, "msg_prior");
                let f_sid = g.add_state(f.clone());
                let pred = g.add_edge(n, "msg_pred");
                g.add_node(NodeKind::Multiply { a: f_sid }, vec![prior], pred, "motion_mul");
                let q = g.add_input_edge(n, "msg_q");
                let noisy = g.add_edge(n, "msg_noisy");
                g.add_node(NodeKind::Add, vec![pred, q], noisy, "motion_add");
                g.cn_sections(n, noisy, &a_list);
            }
        }
        let s = Schedule::forward_sweep(&g);
        Ok((g, s))
    }

    fn inputs(
        &self,
        graph: &FactorGraph,
        schedule: &Schedule,
    ) -> Result<HashMap<MsgId, GaussMessage>> {
        let mut map = HashMap::new();
        map.insert(preload_id(graph, schedule, "msg_prior")?, self.problem.prior.clone());
        if let Some((_, noise)) = &self.problem.motion {
            map.insert(preload_id(graph, schedule, "msg_q")?, noise.clone());
        }
        let obs: Vec<GaussMessage> = self.sections.iter().map(|s| s.obs.clone()).collect();
        bind_streamed(graph, schedule, &obs, &mut map)?;
        Ok(map)
    }

    fn outcome(&self, exec: &Execution) -> Result<GaussMessage> {
        exec.output().cloned()
    }

    /// Posterior uncertainty (lower is better across engines).
    fn quality(&self, outcome: &GaussMessage) -> f64 {
        outcome.trace_cov()
    }

    /// The Q5.10 datapath quantizes tight observation covariances near
    /// the LSB; the posterior trace must stay in golden's regime.
    fn tolerance(&self) -> f64 {
        0.2
    }
}

/// Driver configuration.
#[derive(Clone, Copy, Debug)]
pub struct RelinOptions {
    /// Maximum relinearization rounds.
    pub max_rounds: usize,
    /// Linearization-point movement (max-abs mean delta) below which
    /// the fixed point is declared reached.
    pub tol: f64,
    /// Movement above which the iteration is declared divergent.
    pub divergence: f64,
}

impl Default for RelinOptions {
    fn default() -> Self {
        RelinOptions { max_rounds: 8, tol: 1e-9, divergence: 1e3 }
    }
}

/// Why the driver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RelinStop {
    /// Linearization-point movement fell below the tolerance.
    Converged,
    /// The round budget ran out before the tolerance was met.
    MaxRounds,
    /// Movement exceeded the divergence bound or became non-finite.
    Diverged,
}

/// Result of an iterated-relinearization solve.
#[derive(Clone, Debug)]
pub struct RelinReport {
    /// Posterior belief at the final linearization point.
    pub belief: GaussMessage,
    /// Relinearization rounds executed.
    pub rounds: usize,
    /// Why the driver stopped.
    pub stop: RelinStop,
    /// Linearization-point movement per round.
    pub history: Vec<f64>,
    /// Posterior belief after each round.
    pub trace: Vec<GaussMessage>,
    /// Per-round program-cache flags (true = the sweep's compiled
    /// program came from the session cache; empty on the raw-executor
    /// path, which has no cache observability).
    pub cached: Vec<bool>,
}

impl RelinReport {
    /// True when the driver reached the movement tolerance.
    pub fn converged(&self) -> bool {
        self.stop == RelinStop::Converged
    }
}

/// The relinearization loop: re-linearize → run → move the point.
pub struct IteratedRelinearization<'l> {
    /// Linearizer used for every factor, every round.
    pub linearizer: &'l dyn Linearizer,
    /// Convergence configuration.
    pub opts: RelinOptions,
}

impl<'l> IteratedRelinearization<'l> {
    /// Driver with default options.
    pub fn new(linearizer: &'l dyn Linearizer) -> Self {
        IteratedRelinearization { linearizer, opts: RelinOptions::default() }
    }

    /// Driver with explicit options.
    pub fn with_options(linearizer: &'l dyn Linearizer, opts: RelinOptions) -> Self {
        IteratedRelinearization { linearizer, opts }
    }

    /// Run to the fixed point through a [`Session`] (any engine), with
    /// cache observability per round.
    pub fn run(&self, session: &mut Session, problem: &NonlinearProblem) -> Result<RelinReport> {
        self.drive(problem, |sweep| {
            let r = session.run(sweep)?;
            Ok((r.outcome, Some(r.cached)))
        })
    }

    /// Run through any [`RoundExecutor`] — a session or an
    /// [`crate::coordinator::FgpFarm`] sharding rounds across devices.
    pub fn run_with(
        &self,
        exec: &mut dyn RoundExecutor,
        problem: &NonlinearProblem,
    ) -> Result<RelinReport> {
        self.drive(problem, |sweep| {
            let req = sweep.to_request()?;
            let out = exec
                .run_batch(std::slice::from_ref(&req))?
                .pop()
                .context("executor returned no output for the sweep")?;
            Ok((out, None))
        })
    }

    fn drive(
        &self,
        problem: &NonlinearProblem,
        mut run_sweep: impl FnMut(&RelinSweep) -> Result<(GaussMessage, Option<bool>)>,
    ) -> Result<RelinReport> {
        problem.check()?;
        if self.opts.max_rounds == 0 {
            bail!("max_rounds must be at least 1");
        }
        let mut lin = problem.predicted_prior();
        let mut history = Vec::new();
        let mut trace = Vec::new();
        let mut cached = Vec::new();
        let mut stop = RelinStop::MaxRounds;
        for _ in 0..self.opts.max_rounds {
            let sweep = RelinSweep::linearize_at(problem, &lin, self.linearizer)?;
            let (posterior, cache_flag) = run_sweep(&sweep)?;
            let delta = max_abs_delta(&real_mean(&lin), &real_mean(&posterior));
            history.push(delta);
            trace.push(posterior.clone());
            if let Some(c) = cache_flag {
                cached.push(c);
            }
            lin = posterior;
            if !delta.is_finite() || delta > self.opts.divergence {
                stop = RelinStop::Diverged;
                break;
            }
            if delta < self.opts.tol {
                stop = RelinStop::Converged;
                break;
            }
        }
        Ok(RelinReport { belief: lin, rounds: history.len(), stop, history, trace, cached })
    }
}

fn max_abs_delta(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

// ---------------------------------------------------------------------
// Dense Gauss–Newton reference
// ---------------------------------------------------------------------

/// Reference MAP solve: undamped Gauss–Newton on the nonlinear
/// least-squares objective
/// `(x−μ)ᵀV⁻¹(x−μ) + Σ (z−h(x))ᵀR⁻¹(z−h(x))`, returning the Laplace
/// posterior `N(x*, H⁻¹)`. Feasible for test-sized problems; the
/// iterated driver exists precisely because serving wants fixed-shape
/// device sweeps instead of host-side dense solves.
pub fn gauss_newton(
    problem: &NonlinearProblem,
    max_iters: usize,
    tol: f64,
) -> Result<GaussMessage> {
    use crate::gmp::matrix::{c64, CMatrix};
    problem.check()?;
    let n = problem.n;
    let prior = problem.predicted_prior();
    let mu = real_mean(&prior);
    let w0 = super::linearize::real_symmetric(&prior.cov)
        .inverse()
        .context("gauss-newton: prior covariance is singular")?;

    let mut x = mu.clone();
    let mut h_final = w0.clone();
    for _ in 0..max_iters {
        let mut h = w0.clone();
        let mut g = vec![0.0; n];
        // prior pull: W0 (mu - x)
        for i in 0..n {
            for j in 0..n {
                g[i] += w0[(i, j)].re * (mu[j] - x[j]);
            }
        }
        for f in &problem.factors {
            let j = f.jacobian(&x)?;
            let r: Vec<f64> = f
                .eval(&x)?
                .iter()
                .zip(&f.z)
                .map(|(hx, z)| z - hx)
                .collect();
            let winv = 1.0 / f.noise_var;
            for a in 0..f.m {
                for i in 0..n {
                    g[i] += j[a][i] * winv * r[a];
                    for k in 0..n {
                        h[(i, k)] = h[(i, k)] + c64::new(j[a][i] * winv * j[a][k], 0.0);
                    }
                }
            }
        }
        let mut gm = CMatrix::zeros(n, 1);
        for (i, v) in g.iter().enumerate() {
            gm[(i, 0)] = c64::new(*v, 0.0);
        }
        let delta = h.solve(&gm).context("gauss-newton: normal equations singular")?;
        let mut step = 0.0_f64;
        for i in 0..n {
            x[i] += delta[(i, 0)].re;
            step = step.max(delta[(i, 0)].re.abs());
        }
        h_final = h;
        if step < tol {
            break;
        }
    }
    let cov = h_final
        .inverse()
        .context("gauss-newton: information matrix singular at the optimum")?;
    let mean: Vec<c64> = x.iter().map(|v| c64::new(*v, 0.0)).collect();
    Ok(GaussMessage::new(mean, cov))
}
