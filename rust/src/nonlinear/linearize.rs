//! Pluggable linearizers: nonlinear factor → compound-observation section.
//!
//! Petersen et al., *On Approximate Nonlinear Gaussian Message Passing
//! on Factor Graphs* (2019) gives two families of Gaussian
//! approximations for a nonlinear node `z = h(x) + v`:
//!
//! * **first-order** (EKF-style): Taylor-expand `h` at the incoming
//!   mean, `h(x) ≈ h(x₀) + J·(x − x₀)` — the linearized model is
//!   `z_eff = J x + v` with pseudo-measurement
//!   `z_eff = z − h(x₀) + J x₀`;
//! * **sigma-point** (unscented / statistical linearization): propagate
//!   deterministically chosen sigma points of the incoming belief
//!   through `h`, then fit the affine model `h(x) ≈ A x + b` that
//!   matches the joint second moments; the fit residual
//!   `P_yy − A P A^T` widens the effective observation noise, so the
//!   approximation accounts for curvature the Jacobian misses.
//!
//! Either way the output is a [`Linearization`] — a state matrix plus a
//! pseudo-observation message — which is **exactly** the input contract
//! of the compound-observation node the compiler already lowers and the
//! device already executes. Both linearizers are exact on affine `h`
//! (pinned to 1e-9 by `rust/tests/property_nonlinear.rs`).

use anyhow::{bail, Context, Result};

use crate::gmp::matrix::{c64, CMatrix};
use crate::gmp::message::GaussMessage;

use super::factor::{pad_matrix, pad_vector, real_mean, NonlinearFactor, PairwiseNonlinear};

/// A linearized nonlinear factor: the inputs of one compound-observation
/// section (`A` state matrix + pseudo-observation message), ready for
/// the existing compiler/engine path.
#[derive(Clone, Debug)]
pub struct Linearization {
    /// `n×n` state matrix; rows `0..m` carry the linearized model, the
    /// rest are zero (pure-noise rows, no information).
    pub a: CMatrix,
    /// Pseudo-observation: mean = effective measurement, covariance =
    /// observation noise (plus the statistical-linearization residual
    /// for sigma-point linearizers).
    pub obs: GaussMessage,
}

/// Turns a [`NonlinearFactor`] into the linear compound-observation
/// section the engine executes, given the belief to linearize at.
pub trait Linearizer {
    /// Short identifier for reports ("ekf", "ukf", ...).
    fn name(&self) -> &'static str;

    /// Linearize `f` at the belief `at` (first-order uses the mean;
    /// sigma-point uses mean *and* covariance).
    fn linearize(&self, f: &NonlinearFactor, at: &GaussMessage) -> Result<Linearization>;
}

// ---------------------------------------------------------------------
// First-order (EKF-style)
// ---------------------------------------------------------------------

/// Jacobian linearization at the belief mean (analytic Jacobian when the
/// factor carries one, central differences otherwise).
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstOrder;

impl Linearizer for FirstOrder {
    fn name(&self) -> &'static str {
        "ekf"
    }

    fn linearize(&self, f: &NonlinearFactor, at: &GaussMessage) -> Result<Linearization> {
        let x0 = real_mean(at);
        let h0 = f.eval(&x0).context("first-order linearization: h(x0)")?;
        let j = f.jacobian(&x0).context("first-order linearization: Jacobian")?;
        // z_eff = z - h(x0) + J x0
        let z_eff: Vec<f64> = (0..f.m)
            .map(|r| {
                let jx0: f64 = j[r].iter().zip(&x0).map(|(a, b)| a * b).sum();
                f.z[r] - h0[r] + jx0
            })
            .collect();
        Ok(Linearization {
            a: pad_matrix(&j, f.n),
            obs: GaussMessage::new(
                pad_vector(&z_eff, f.n),
                CMatrix::scaled_identity(f.n, f.noise_var),
            ),
        })
    }
}

// ---------------------------------------------------------------------
// Sigma-point (unscented / statistical linearization)
// ---------------------------------------------------------------------

/// Scaled unscented transform weights and sigma points (Julier &
/// Uhlmann; Petersen et al. 2019 §sigma-point methods).
#[derive(Clone, Copy, Debug)]
pub struct SigmaPoint {
    /// Spread of the sigma points around the mean (default 1.0).
    pub alpha: f64,
    /// Prior-knowledge-of-distribution weight on the center covariance
    /// term (2.0 is optimal for Gaussians).
    pub beta: f64,
    /// Secondary scaling; `None` picks the Gaussian-kurtosis-matching
    /// `3 − n` at linearization time.
    pub kappa: Option<f64>,
}

impl Default for SigmaPoint {
    fn default() -> Self {
        SigmaPoint { alpha: 1.0, beta: 2.0, kappa: None }
    }
}

/// Moments of the unscented pushforward (exposed for the property
/// suite: the UT must reproduce mean/covariance of a linear map).
#[derive(Clone, Debug)]
pub struct UtStats {
    /// Input mean (real part), length `n`.
    pub xbar: Vec<f64>,
    /// Pushforward mean, length `m`.
    pub ybar: Vec<f64>,
    /// Pushforward covariance (`m×m`, real).
    pub pyy: CMatrix,
    /// Input/output cross-covariance (`n×m`, real).
    pub pxy: CMatrix,
}

impl SigmaPoint {
    /// Sigma-point weights from explicit α/β/κ.
    pub fn new(alpha: f64, beta: f64, kappa: f64) -> Self {
        SigmaPoint { alpha, beta, kappa: Some(kappa) }
    }

    fn lambda(&self, n: usize) -> f64 {
        let kappa = self.kappa.unwrap_or(3.0 - n as f64);
        self.alpha * self.alpha * (n as f64 + kappa) - n as f64
    }

    /// Mean and covariance weights for an `n`-dim state: `2n + 1`
    /// entries each; the mean weights sum to one. The scaling
    /// `n + λ = α²(n + κ)` must be positive — a contract on the
    /// constructor parameters, asserted here (the fallible path through
    /// [`SigmaPoint::unscented_stats`] returns the same condition as an
    /// error).
    pub fn weights(&self, n: usize) -> (Vec<f64>, Vec<f64>) {
        let lambda = self.lambda(n);
        let denom = n as f64 + lambda;
        assert!(
            denom > 0.0,
            "sigma-point scaling n + lambda = {denom} must be positive \
             (alpha {}, kappa {:?})",
            self.alpha,
            self.kappa
        );
        let wi = 1.0 / (2.0 * denom);
        let mut wm = vec![wi; 2 * n + 1];
        let mut wc = vec![wi; 2 * n + 1];
        wm[0] = lambda / denom;
        wc[0] = lambda / denom + (1.0 - self.alpha * self.alpha + self.beta);
        (wm, wc)
    }

    /// Unscented pushforward of `at` through the factor's `h`.
    pub fn unscented_stats(&self, f: &NonlinearFactor, at: &GaussMessage) -> Result<UtStats> {
        let n = f.n;
        if at.dim() != n {
            bail!("belief has dim {} but the factor expects n={n}", at.dim());
        }
        let lambda = self.lambda(n);
        if n as f64 + lambda <= 0.0 {
            bail!(
                "sigma-point scaling n + lambda = {} must be positive (alpha {}, kappa {:?})",
                n as f64 + lambda,
                self.alpha,
                self.kappa
            );
        }
        let (wm, wc) = self.weights(n);
        let xbar = real_mean(at);
        let scaled = real_symmetric(&at.cov).scale(n as f64 + lambda);
        let l = cholesky_lower(&scaled).context("sigma points: covariance square root")?;

        // 2n + 1 sigma points: mean, mean ± columns of L
        let mut chis = Vec::with_capacity(2 * n + 1);
        chis.push(xbar.clone());
        for i in 0..n {
            let col: Vec<f64> = (0..n).map(|r| l[(r, i)].re).collect();
            chis.push(xbar.iter().zip(&col).map(|(a, b)| a + b).collect());
            chis.push(xbar.iter().zip(&col).map(|(a, b)| a - b).collect());
        }
        let ys: Vec<Vec<f64>> = chis
            .iter()
            .map(|chi| f.eval(chi))
            .collect::<Result<_>>()
            .context("sigma points: evaluating h")?;

        let m = f.m;
        let mut ybar = vec![0.0; m];
        for (w, y) in wm.iter().zip(&ys) {
            for (acc, v) in ybar.iter_mut().zip(y) {
                *acc += w * v;
            }
        }
        let mut pyy = CMatrix::zeros(m, m);
        let mut pxy = CMatrix::zeros(n, m);
        for ((w, chi), y) in wc.iter().zip(&chis).zip(&ys) {
            let dy: Vec<f64> = y.iter().zip(&ybar).map(|(a, b)| a - b).collect();
            let dx: Vec<f64> = chi.iter().zip(&xbar).map(|(a, b)| a - b).collect();
            for i in 0..m {
                for j in 0..m {
                    pyy[(i, j)] = pyy[(i, j)] + c64::new(w * dy[i] * dy[j], 0.0);
                }
            }
            for i in 0..n {
                for j in 0..m {
                    pxy[(i, j)] = pxy[(i, j)] + c64::new(w * dx[i] * dy[j], 0.0);
                }
            }
        }
        Ok(UtStats { xbar, ybar, pyy, pxy })
    }
}

impl Linearizer for SigmaPoint {
    fn name(&self) -> &'static str {
        "ukf"
    }

    fn linearize(&self, f: &NonlinearFactor, at: &GaussMessage) -> Result<Linearization> {
        let s = self.unscented_stats(f, at)?;
        let n = f.n;
        let m = f.m;
        // statistical linearization: A = P_xy^T P^{-1} (fits h ≈ A x + b
        // in the joint-moment sense)
        let p = real_symmetric(&at.cov);
        let pinv_pxy = p
            .solve(&s.pxy)
            .context("sigma-point linearization: input covariance is singular")?;
        let a_lin = pinv_pxy.transpose(); // m×n, real
        // fit residual widens the effective observation noise;
        // symmetrize (into a copy — in-place would skew the upper
        // half) and clamp round-off negatives on the diagonal
        let raw = s.pyy.sub(&a_lin.matmul(&p).matmul(&a_lin.transpose()));
        let mut resid = CMatrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                resid[(i, j)] = c64::new((raw[(i, j)].re + raw[(j, i)].re) / 2.0, 0.0);
            }
        }
        for i in 0..m {
            if resid[(i, i)].re < 0.0 {
                resid[(i, i)] = c64::ZERO;
            }
        }
        // z_eff = z - b = z - ybar + A xbar
        let z_eff: Vec<f64> = (0..m)
            .map(|r| {
                let ax: f64 = (0..n).map(|j| a_lin[(r, j)].re * s.xbar[j]).sum();
                f.z[r] - s.ybar[r] + ax
            })
            .collect();
        let mut cov = CMatrix::scaled_identity(n, f.noise_var);
        for i in 0..m {
            for j in 0..m {
                cov[(i, j)] = cov[(i, j)] + resid[(i, j)];
            }
        }
        // embed the m×n fit into the device's n×n state matrix
        let mut a = CMatrix::zeros(n, n);
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = a_lin[(i, j)];
            }
        }
        Ok(Linearization { a, obs: GaussMessage::new(pad_vector(&z_eff, n), cov) })
    }
}

// ---------------------------------------------------------------------
// Pairwise linearization (GBP relative factors)
// ---------------------------------------------------------------------

/// A linearized pairwise factor: `z_eff ≈ A_from x_from + A_to x_to + v`
/// with `v ~ N(0, obs.cov)` — the joint-linear stand-in the GBP bridge
/// lowers to multiplier/adder/compound chains and the dense reference
/// assembles into the joint information matrix.
#[derive(Clone, Debug)]
pub struct PairRelin {
    /// Linearized map of the `from` endpoint.
    pub a_from: CMatrix,
    /// Linearized map of the `to` endpoint.
    pub a_to: CMatrix,
    /// mean = effective measurement `z − h(x₀) + A_f x₀f + A_t x₀t`
    /// (padded to `n`); cov = observation noise plus both endpoints'
    /// statistical-linearization residuals.
    pub obs: GaussMessage,
}

impl PairwiseNonlinear {
    /// Linearize at the two endpoint beliefs through any [`Linearizer`]
    /// (each endpoint is linearized with the other frozen at its mean).
    pub fn linearize_with(
        &self,
        linearizer: &dyn Linearizer,
        belief_from: &GaussMessage,
        belief_to: &GaussMessage,
    ) -> Result<PairRelin> {
        let xf = real_mean(belief_from);
        let xt = real_mean(belief_to);
        let lf = linearizer
            .linearize(&self.adapter_from(&xt)?, belief_from)
            .context("pairwise linearization (from side)")?;
        let lt = linearizer
            .linearize(&self.adapter_to(&xf)?, belief_to)
            .context("pairwise linearization (to side)")?;
        let h0 = self.eval(&xf, &xt)?;
        // joint affine fit h ≈ A_f x_f + A_t x_t + c with
        // c = b_f + b_t − h(x₀) (each endpoint's intercept counted
        // once; exact for the Jacobian linearizer, and keeping the
        // sigma-point curvature corrections b − h(x₀) of both sides).
        // Each per-endpoint linearization reports b via obs.mean = z − b.
        let z_eff: Vec<f64> = (0..self.m)
            .map(|r| lf.obs.mean[r].re + lt.obs.mean[r].re - self.z[r] + h0[r])
            .collect();
        // noise + residual_f + residual_t (each lin cov = noise + its
        // own residual, so summing and removing one noise term keeps
        // exactly one copy of the noise)
        let base = CMatrix::scaled_identity(self.n, self.noise_var);
        let cov = lf.obs.cov.add(&lt.obs.cov).sub(&base);
        Ok(PairRelin {
            a_from: lf.a,
            a_to: lt.a,
            obs: GaussMessage::new(pad_vector(&z_eff, self.n), cov),
        })
    }
}

// ---------------------------------------------------------------------
// Small real-matrix helpers
// ---------------------------------------------------------------------

/// Real symmetric part of a (Hermitian) covariance: `(Re V + Re V^T)/2`
/// — the matrix the real-valued nonlinear machinery (sigma points,
/// Gauss–Newton) operates on.
pub fn real_symmetric(v: &CMatrix) -> CMatrix {
    let n = v.rows;
    let mut out = CMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            out[(i, j)] = c64::new((v[(i, j)].re + v[(j, i)].re) / 2.0, 0.0);
        }
    }
    out
}

/// Lower Cholesky factor of a real symmetric PSD matrix, retrying with
/// escalating diagonal jitter (sigma points tolerate a slightly
/// regularized square root; the ladder tops out above the Q5.10 LSB so
/// device-quantized beliefs — which can be marginally indefinite —
/// still linearize). A hard failure means the belief covariance is
/// broken.
fn cholesky_lower(p: &CMatrix) -> Result<CMatrix> {
    let n = p.rows;
    for jitter in [0.0, 1e-12, 1e-9, 1e-6, 4e-3] {
        if let Some(l) = try_cholesky(p, n, jitter) {
            return Ok(l);
        }
    }
    bail!("covariance is not positive definite (cholesky failed at jitter 4e-3)")
}

fn try_cholesky(p: &CMatrix, n: usize, jitter: f64) -> Option<CMatrix> {
    let mut l = CMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = p[(i, j)].re;
            if i == j {
                s += jitter;
            }
            for k in 0..j {
                s -= l[(i, k)].re * l[(j, k)].re;
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = c64::new(s.sqrt(), 0.0);
            } else {
                l[(i, j)] = c64::new(s / l[(j, j)].re, 0.0);
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use std::sync::Arc;

    fn range_factor(n: usize, anchor: (f64, f64), z: f64, var: f64) -> NonlinearFactor {
        NonlinearFactor::new(
            n,
            1,
            Arc::new(move |x: &[f64]| {
                vec![((x[0] - anchor.0).powi(2) + (x[1] - anchor.1).powi(2)).sqrt()]
            }),
            vec![z],
            var,
        )
        .unwrap()
    }

    fn belief(rng: &mut Rng, n: usize) -> GaussMessage {
        GaussMessage::new(
            (0..n).map(|_| c64::new(rng.range(0.2, 0.8), 0.0)).collect(),
            CMatrix::scaled_identity(n, 0.1),
        )
    }

    #[test]
    fn numeric_jacobian_matches_analytic_on_range() {
        let n = 4;
        let f = range_factor(n, (0.0, 0.0), 0.5, 1e-3);
        let x = [0.3, 0.4, 0.0, 0.0];
        let j = f.jacobian(&x).unwrap();
        // analytic: unit vector towards x
        let d = 0.5;
        assert!((j[0][0] - 0.3 / d).abs() < 1e-6);
        assert!((j[0][1] - 0.4 / d).abs() < 1e-6);
        assert!(j[0][2].abs() < 1e-9 && j[0][3].abs() < 1e-9);
    }

    #[test]
    fn first_order_and_sigma_agree_on_gentle_curvature() {
        let mut rng = Rng::new(7);
        let n = 4;
        let f = range_factor(n, (-0.5, -0.5), 1.1, 1e-3);
        let at = belief(&mut rng, n);
        let ekf = FirstOrder.linearize(&f, &at).unwrap();
        let ukf = SigmaPoint::default().linearize(&f, &at).unwrap();
        assert!(ekf.a.dist(&ukf.a) < 0.2, "dist {}", ekf.a.dist(&ukf.a));
        // the UT's curvature correction (~½ tr(H·P)) bounds the
        // pseudo-measurement gap at this geometry
        assert!(
            (ekf.obs.mean[0] - ukf.obs.mean[0]).abs() < 0.1,
            "pseudo-measurements differ: {} vs {}",
            ekf.obs.mean[0],
            ukf.obs.mean[0]
        );
    }

    #[test]
    fn sigma_residual_widens_noise_under_curvature() {
        let n = 4;
        // strong curvature: target close to the anchor, wide belief
        let f = range_factor(n, (0.45, 0.45), 0.2, 1e-4);
        let at = GaussMessage::new(
            vec![c64::new(0.5, 0.0), c64::new(0.5, 0.0), c64::ZERO, c64::ZERO],
            CMatrix::scaled_identity(n, 0.2),
        );
        let lin = SigmaPoint::default().linearize(&f, &at).unwrap();
        assert!(
            lin.obs.cov[(0, 0)].re > f.noise_var,
            "residual must widen the observation noise: {} vs {}",
            lin.obs.cov[(0, 0)].re,
            f.noise_var
        );
    }

    #[test]
    fn pairwise_linearization_is_antisymmetric_for_range() {
        let n = 4;
        let f = PairwiseNonlinear::new(
            n,
            1,
            Arc::new(|a: &[f64], b: &[f64]| {
                vec![((b[0] - a[0]).powi(2) + (b[1] - a[1]).powi(2)).sqrt()]
            }),
            vec![0.5],
            1e-3,
        )
        .unwrap();
        let bf = GaussMessage::new(
            vec![c64::new(0.1, 0.0), c64::new(0.1, 0.0), c64::ZERO, c64::ZERO],
            CMatrix::scaled_identity(n, 0.05),
        );
        let bt = GaussMessage::new(
            vec![c64::new(0.5, 0.0), c64::new(0.4, 0.0), c64::ZERO, c64::ZERO],
            CMatrix::scaled_identity(n, 0.05),
        );
        let pr = f.linearize_with(&FirstOrder, &bf, &bt).unwrap();
        // d|b-a|/da = -(b-a)/d, d|b-a|/db = +(b-a)/d
        for j in 0..2 {
            assert!(
                (pr.a_from[(0, j)].re + pr.a_to[(0, j)].re).abs() < 1e-5,
                "range Jacobians must be antisymmetric"
            );
        }
    }
}
