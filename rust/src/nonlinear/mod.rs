//! S14 — Nonlinear Gaussian message passing over the engine surface.
//!
//! The paper's message-update engine is exact only for linear-Gaussian
//! nodes, yet the workloads it targets at scale — localization,
//! tracking, receivers — are nonlinear. This subsystem closes that gap
//! the way Petersen et al., *On Approximate Nonlinear Gaussian Message
//! Passing on Factor Graphs* (2019) prescribe: approximate each
//! nonlinear node by a linear-Gaussian stand-in, iterate the
//! approximation point to a Gauss–Newton-style fixed point, and let the
//! existing linear machinery do all the arithmetic.
//!
//! * [`factor`] — [`NonlinearFactor`] (`z = h(x) + v` on one variable)
//!   and [`PairwiseNonlinear`] (`z = h(x_from, x_to) + v` between two),
//!   with analytic or central-difference Jacobians;
//! * [`linearize`] — the pluggable [`Linearizer`] trait with two
//!   implementations: [`FirstOrder`] (EKF-style Jacobian expansion) and
//!   [`SigmaPoint`] (unscented statistical linearization with
//!   configurable α/β/κ weights, fit residual widening the effective
//!   noise). Either emits a [`Linearization`] — precisely the state
//!   matrix + observation pair of the compound-observation node the
//!   compiler already lowers;
//! * [`driver`] — [`IteratedRelinearization`] sweeps re-linearize → run
//!   → update-point over a [`NonlinearProblem`]; every round is a
//!   [`RelinSweep`] workload of **fixed graph shape**, so rounds after
//!   the first are program-cache hits on the [`crate::engine::Session`]
//!   (and the whole sweep can ship through a
//!   [`crate::coordinator::FgpFarm`]). [`gauss_newton`] is the dense
//!   reference the fixed point is validated against.
//!
//! The GBP layer consumes the same trait: [`crate::gbp::GbpModel`]
//! accepts nonlinear unary/pairwise factors and the solver relinearizes
//! them at the current beliefs every round (Ortiz et al. 2021) — see
//! `crate::gbp::bridge::RelinContext`.
//!
//! ```
//! use std::sync::Arc;
//! use fgp_repro::engine::Session;
//! use fgp_repro::gmp::matrix::{c64, CMatrix};
//! use fgp_repro::gmp::message::GaussMessage;
//! use fgp_repro::nonlinear::{
//!     gauss_newton, FirstOrder, IteratedRelinearization, NonlinearFactor, NonlinearProblem,
//!     RelinOptions,
//! };
//!
//! // observe the square of the first state component: z = x0² + v
//! let n = 4;
//! let h = Arc::new(|x: &[f64]| vec![x[0] * x[0]]);
//! let factor = NonlinearFactor::new(n, 1, h, vec![4.0], 1e-3).unwrap();
//! let mut mean = vec![c64::ZERO; n];
//! mean[0] = c64::new(1.5, 0.0); // start near the x0 = 2 solution
//! let prior = GaussMessage::new(mean, CMatrix::scaled_identity(n, 0.5));
//! let problem = NonlinearProblem { n, prior, motion: None, factors: vec![factor] };
//!
//! // iterated relinearization over the engine == dense Gauss–Newton
//! let opts = RelinOptions { max_rounds: 20, ..Default::default() };
//! let driver = IteratedRelinearization::with_options(&FirstOrder, opts);
//! let report = driver.run(&mut Session::golden(), &problem).unwrap();
//! let reference = gauss_newton(&problem, 50, 1e-12).unwrap();
//! assert!(report.converged());
//! assert!((report.belief.mean[0].re - reference.mean[0].re).abs() < 1e-6);
//! ```
//!
//! Contract, pinned by `rust/tests/property_nonlinear.rs`:
//!
//! 1. both linearizers are **exact** (≤ 1e-9) on affine `h`;
//! 2. sigma-point mean weights sum to 1 and the unscented transform
//!    reproduces the mean/covariance of a linear pushforward;
//! 3. the iterated driver's fixed point matches the dense Gauss–Newton
//!    solve on the range model.

pub mod driver;
pub mod factor;
pub mod linearize;

pub use driver::{
    gauss_newton, IteratedRelinearization, NonlinearProblem, RelinOptions, RelinReport,
    RelinStop, RelinSweep,
};
pub use factor::{
    pad_matrix, pad_vector, real_mean, H2Fn, HFn, JFn, NonlinearFactor, PairwiseNonlinear,
};
pub use linearize::{
    real_symmetric, FirstOrder, Linearization, Linearizer, PairRelin, SigmaPoint, UtStats,
};
