//! Deterministic randomness + property-testing helpers.
//!
//! The vendored crate set has neither `rand` nor `proptest`, so tests and
//! workload generators use this self-contained xorshift64* generator and a
//! tiny case-runner that reports the failing seed for reproduction.

/// xorshift64* PRNG — deterministic, seedable, good enough for test data.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    /// A generator seeded deterministically (same seed, same stream).
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng(seed.wrapping_mul(2685821657736338717).max(1))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(2685821657736338717)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Run `cases` property-test cases, each with a fresh seeded [`Rng`];
/// panics with the offending seed on failure so the case can be replayed.
pub fn proptest_cases(cases: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats agree to `tol` absolute or relative tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol}, scale {scale})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn rng_normal_has_sane_moments() {
        let mut rng = Rng::new(2);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn proptest_reports_seed() {
        proptest_cases(3, |rng| {
            assert!(rng.uniform() < 0.0, "always fails");
        });
    }

    #[test]
    fn assert_close_accepts_relative() {
        assert_close(1000.0, 1000.4, 1e-3);
    }
}
