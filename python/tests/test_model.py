"""L2 model tests: RLS chain, Kalman pass, shape contracts."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def make_rls_problem(rng, n, sections, sigma2=0.1):
    """Random RLS channel-estimation instance in block form.

    The regressor for section i is the (complex) outer structure the
    paper's Fig. 6 uses: a known symbol row observed through noise.  We
    embed the 1 x n complex row as an n x n matrix with the row in the
    first position and a tiny ridge elsewhere so G stays invertible —
    exactly the convention the Rust apps::rls module uses.
    """
    h_true = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    a_seq, y_seq = [], []
    for _ in range(sections):
        row = rng.standard_normal(n) + 1j * rng.standard_normal(n)
        a = np.zeros((n, n), dtype=complex)
        a[0, :] = row
        noise = (rng.standard_normal() + 1j * rng.standard_normal()) * np.sqrt(sigma2 / 2)
        y = np.zeros(n, dtype=complex)
        y[0] = row @ h_true + noise
        a_seq.append(ref.blk(jnp.array(a)))
        y_seq.append(ref.vecblk(jnp.array(y)))
    v0 = ref.blk(jnp.array(np.eye(n, dtype=complex) * 10.0))
    m0 = ref.vecblk(jnp.array(np.zeros(n, dtype=complex)))
    return h_true, v0, m0, jnp.stack(a_seq), jnp.stack(y_seq)


@pytest.mark.parametrize("sections", [1, 4, 16])
def test_rls_chain_matches_sequential_ref(sections):
    rng = np.random.default_rng(0)
    _, v0, m0, a_seq, y_seq = make_rls_problem(rng, 4, sections)
    v_k, m_k = model.rls_chain(v0, m0, a_seq, y_seq, jnp.float32(0.1))
    v_r, m_r = ref.rls_chain_ref(v0, m0, a_seq, y_seq, 0.1)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), rtol=1e-3, atol=1e-3)


def test_rls_chain_pallas_vs_pure_jnp_twin():
    rng = np.random.default_rng(1)
    _, v0, m0, a_seq, y_seq = make_rls_problem(rng, 4, 8)
    v_k, m_k = model.rls_chain(v0, m0, a_seq, y_seq, jnp.float32(0.1))
    v_j, m_j = model.rls_chain_ref(v0, m0, a_seq, y_seq, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_j), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_j), rtol=1e-3, atol=1e-3)


def test_rls_converges_to_true_channel():
    """The headline behaviour: estimate -> true channel as sections grow."""
    rng = np.random.default_rng(2)
    n, sections = 4, 64
    h_true, v0, m0, a_seq, y_seq = make_rls_problem(rng, n, sections, sigma2=0.01)
    _, m_seq = model.rls_chain(v0, m0, a_seq, y_seq, jnp.float32(0.01))
    h_hat = np.asarray(ref.unvecblk(m_seq[-1]))
    err_final = np.linalg.norm(h_hat - h_true) / np.linalg.norm(h_true)
    h_early = np.asarray(ref.unvecblk(m_seq[2]))
    err_early = np.linalg.norm(h_early - h_true) / np.linalg.norm(h_true)
    assert err_final < 0.05, f"final rel err {err_final}"
    assert err_final < err_early, "error must decrease with more sections"


def test_rls_covariance_trace_monotone():
    """Each observation shrinks posterior uncertainty (tr V non-increasing)."""
    rng = np.random.default_rng(3)
    _, v0, m0, a_seq, y_seq = make_rls_problem(rng, 4, 16)
    v_seq, _ = model.rls_chain(v0, m0, a_seq, y_seq, jnp.float32(0.1))
    traces = [float(jnp.trace(v)) for v in v_seq]
    traces = [float(jnp.trace(v0))] + traces
    assert all(t1 <= t0 + 1e-4 for t0, t1 in zip(traces, traces[1:]))


def test_kalman_pass_tracks_constant_velocity():
    """2-state constant-velocity tracker: position error stays bounded."""
    rng = np.random.default_rng(4)
    n, steps, dt = 2, 50, 1.0
    a = np.array([[1.0, dt], [0.0, 1.0]], dtype=complex)
    c = np.array([[1.0, 0.0], [0.0, 0.0]], dtype=complex)  # observe position
    q = ref.blk(jnp.array(np.eye(n, dtype=complex) * 1e-3))
    r = ref.blk(jnp.array(np.eye(n, dtype=complex) * 0.1))
    x = np.array([0.0, 1.0], dtype=complex)
    a_b = ref.blk(jnp.array(a))
    c_b = ref.blk(jnp.array(c))
    a_seq, c_seq, y_seq, xs = [], [], [], []
    for _ in range(steps):
        x = a @ x
        y = np.zeros(n, dtype=complex)
        y[0] = x[0] + rng.standard_normal() * 0.3
        a_seq.append(a_b)
        c_seq.append(c_b)
        y_seq.append(ref.vecblk(jnp.array(y)))
        xs.append(x.copy())
    v0 = ref.blk(jnp.array(np.eye(n, dtype=complex) * 5.0))
    m0 = ref.vecblk(jnp.array(np.zeros(n, dtype=complex)))
    v_seq, m_seq = model.kalman_smoother_pass(
        v0, m0, jnp.stack(a_seq), jnp.stack(c_seq), q, r, jnp.stack(y_seq)
    )
    est = np.asarray(ref.unvecblk(m_seq[-1]))
    truth = xs[-1]
    assert abs(est[0] - truth[0]) < 1.0, f"position err {abs(est[0]-truth[0])}"
    assert abs(est[1] - truth[1]) < 0.5, f"velocity err {abs(est[1]-truth[1])}"


def test_example_args_shapes():
    assert [tuple(s.shape) for s in model.cn_example_args(4)] == [
        (8, 8), (8, 8), (8, 8), (8,), (8,)
    ]
    assert [tuple(s.shape) for s in model.cn_batched_example_args(4, 32)][0] == (32, 8, 8)
    shapes = [tuple(s.shape) for s in model.rls_example_args(4, 64)]
    assert shapes == [(8, 8), (8,), (64, 8, 8), (64, 8), ()]
