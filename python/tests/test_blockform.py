"""Properties of the complex <-> real block embedding (kernels.ref).

The entire L1/L2 stack rests on blk() being an algebra isomorphism; these
tests pin down every identity the kernels rely on.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand_c(rng, *shape):
    return rng.standard_normal(shape) + 1j * rng.standard_normal(shape)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_blk_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    m = rand_c(rng, n, n)
    back = np.asarray(ref.unblk(ref.blk(jnp.array(m))))
    np.testing.assert_allclose(back, m, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_blk_is_multiplicative(n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand_c(rng, n, n), rand_c(rng, n, n)
    lhs = np.asarray(ref.blk(jnp.array(a)) @ ref.blk(jnp.array(b)))
    rhs = np.asarray(ref.blk(jnp.array(a @ b)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_blk_transpose_is_hermitian(n, seed):
    rng = np.random.default_rng(seed)
    a = rand_c(rng, n, n)
    lhs = np.asarray(ref.blk(jnp.array(a)).T)
    rhs = np.asarray(ref.blk(jnp.array(a.conj().T)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
def test_blk_inverse_commutes(n, seed):
    rng = np.random.default_rng(seed)
    a = rand_c(rng, n, n) + np.eye(n) * 3.0  # keep well conditioned
    lhs = np.linalg.inv(np.asarray(ref.blk(jnp.array(a)), dtype=np.float64))
    rhs = np.asarray(ref.blk(jnp.array(np.linalg.inv(a))), dtype=np.float64)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_vecblk_matvec(n, seed):
    rng = np.random.default_rng(seed)
    a, x = rand_c(rng, n, n), rand_c(rng, n)
    lhs = np.asarray(ref.blk(jnp.array(a)) @ ref.vecblk(jnp.array(x)))
    rhs = np.asarray(ref.vecblk(jnp.array(a @ x)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_vecblk_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    x = rand_c(rng, n)
    back = np.asarray(ref.unvecblk(ref.vecblk(jnp.array(x))))
    np.testing.assert_allclose(back, x, rtol=1e-5, atol=1e-5)


def test_blk_of_hermitian_psd_is_symmetric_psd():
    rng = np.random.default_rng(0)
    m = rand_c(rng, 4, 4)
    v = m @ m.conj().T + np.eye(4)
    b = np.asarray(ref.blk(jnp.array(v)), dtype=np.float64)
    np.testing.assert_allclose(b, b.T, atol=1e-5)
    assert np.linalg.eigvalsh(b).min() > 0


def test_simple_node_rules_complex_equivalence():
    """Fig. 1 rules in block form match their complex counterparts."""
    rng = np.random.default_rng(1)
    n = 3
    a = rand_c(rng, n, n)
    msq = rand_c(rng, n, n)
    v = msq @ msq.conj().T + np.eye(n)
    x = rand_c(rng, n)
    vb, xb = ref.blk(jnp.array(v)), ref.vecblk(jnp.array(x))
    ab = ref.blk(jnp.array(a))
    vy_b, my_b = ref.matmul_node_ref(vb, xb, ab)
    np.testing.assert_allclose(
        np.asarray(ref.unblk(vy_b)), a @ v @ a.conj().T, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(ref.unvecblk(my_b)), a @ x, rtol=1e-4, atol=1e-4
    )
