"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Every kernel in ``compile.kernels`` is checked against ``ref.py`` (and,
for the compound node, against the plain complex-arithmetic formula) over
a sweep of sizes and random seeds, plus hypothesis-driven shape/value
sweeps.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compound, faddeev, ref


def rand_psd(rng, n):
    """Random complex positive-definite matrix (well conditioned)."""
    m = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
    return m @ m.conj().T + np.eye(n) * 0.5


def rand_cmat(rng, n):
    return rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))


def rand_cvec(rng, n):
    return rng.standard_normal(n) + 1j * rng.standard_normal(n)


def cn_inputs_blk(rng, n):
    """Random CN-update operands, returned in block-real form + complex."""
    vx, vy = rand_psd(rng, n), rand_psd(rng, n)
    a, mx, my = rand_cmat(rng, n), rand_cvec(rng, n), rand_cvec(rng, n)
    blkset = (
        ref.blk(jnp.array(vx)),
        ref.blk(jnp.array(vy)),
        ref.blk(jnp.array(a)),
        ref.vecblk(jnp.array(mx)),
        ref.vecblk(jnp.array(my)),
    )
    return blkset, (vx, vy, a, mx, my)


TOL = dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# compound-node kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 4, 6, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cn_update_matches_complex_reference(n, seed):
    rng = np.random.default_rng(seed)
    blkset, (vx, vy, a, mx, my) = cn_inputs_blk(rng, n)
    vz_k, mz_k = compound.cn_update(*blkset)
    vz_c, mz_c = ref.cn_update_complex(
        jnp.array(vx), jnp.array(vy), jnp.array(a), jnp.array(mx), jnp.array(my)
    )
    np.testing.assert_allclose(np.asarray(ref.unblk(vz_k)), np.asarray(vz_c), **TOL)
    np.testing.assert_allclose(np.asarray(ref.unvecblk(mz_k)), np.asarray(mz_c), **TOL)


@pytest.mark.parametrize("n", [2, 4])
def test_cn_update_matches_block_reference(n):
    rng = np.random.default_rng(7)
    blkset, _ = cn_inputs_blk(rng, n)
    vz_k, mz_k = compound.cn_update(*blkset)
    vz_r, mz_r = ref.cn_update_blk_ref(*blkset)
    np.testing.assert_allclose(np.asarray(vz_k), np.asarray(vz_r), **TOL)
    np.testing.assert_allclose(np.asarray(mz_k), np.asarray(mz_r), **TOL)


def test_cn_update_output_covariance_is_symmetric_psd():
    """V_Z must stay a valid covariance: block-symmetric, eigenvalues >= 0."""
    rng = np.random.default_rng(3)
    blkset, _ = cn_inputs_blk(rng, 4)
    vz_k, _ = compound.cn_update(*blkset)
    vz = np.asarray(ref.unblk(vz_k))
    np.testing.assert_allclose(vz, vz.conj().T, rtol=1e-3, atol=1e-3)
    eig = np.linalg.eigvalsh((vz + vz.conj().T) / 2)
    assert eig.min() > -1e-4


def test_cn_update_shrinks_covariance():
    """An observation can only reduce uncertainty: tr(V_Z) <= tr(V_X)."""
    rng = np.random.default_rng(4)
    blkset, (vx, *_rest) = cn_inputs_blk(rng, 4)
    vz_k, _ = compound.cn_update(*blkset)
    assert float(np.trace(np.real(np.asarray(ref.unblk(vz_k))))) <= np.trace(vx.real) + 1e-5


@pytest.mark.parametrize("batch", [1, 3, 8])
def test_cn_update_batched_matches_loop(batch):
    rng = np.random.default_rng(5)
    singles = [cn_inputs_blk(rng, 4)[0] for _ in range(batch)]
    stacked = tuple(jnp.stack([s[i] for s in singles]) for i in range(5))
    vz_b, mz_b = compound.cn_update_batched(*stacked)
    for i, s in enumerate(singles):
        vz_i, mz_i = compound.cn_update(*s)
        np.testing.assert_allclose(np.asarray(vz_b[i]), np.asarray(vz_i), **TOL)
        np.testing.assert_allclose(np.asarray(mz_b[i]), np.asarray(mz_i), **TOL)


# ---------------------------------------------------------------------------
# faddeev kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1])
def test_faddeev_matches_schur_ref(n, seed):
    rng = np.random.default_rng(seed)
    m = 2 * n
    g = ref.blk(jnp.array(rand_psd(rng, n)))
    b = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    c = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    d = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    out = faddeev.faddeev(g, b, c, d)
    expect = ref.schur_ref(g, b, c, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **TOL)


def test_faddeev_identity_g_is_plain_mms():
    """With G = I the Schur complement degenerates to D - C B (an mms)."""
    rng = np.random.default_rng(9)
    m = 8
    g = jnp.eye(m, dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    c = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    d = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(faddeev.faddeev(g, b, c, d)), np.asarray(d - c @ b), **TOL
    )


@pytest.mark.parametrize("n", [2, 4])
def test_faddeev_extended_matches_ref(n):
    rng = np.random.default_rng(11)
    m = 2 * n
    g = ref.blk(jnp.array(rand_psd(rng, n)))
    b = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    c = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    d = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    y = jnp.array(rng.standard_normal(m), dtype=jnp.float32)
    x = jnp.array(rng.standard_normal(m), dtype=jnp.float32)
    vz, mz = faddeev.faddeev_extended(g, b, c, d, y, x)
    vz_r, mz_r = ref.faddeev_extended_ref(g, b, c, d, y, x)
    np.testing.assert_allclose(np.asarray(vz), np.asarray(vz_r), **TOL)
    np.testing.assert_allclose(np.asarray(mz), np.asarray(mz_r), **TOL)


# ---------------------------------------------------------------------------
# mma / mms kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 4, 4), (8, 8, 8), (8, 4, 8), (2, 6, 3)])
def test_mm_matches_ref(shape):
    rng = np.random.default_rng(13)
    mi, mk, mj = shape
    a = jnp.array(rng.standard_normal((mi, mk)), dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((mk, mj)), dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(compound.mm(a, b)), np.asarray(ref.mm_ref(a, b)), **TOL
    )


@pytest.mark.parametrize("neg", [True, False])
def test_mms_matches_ref(neg):
    rng = np.random.default_rng(17)
    m = 8
    c = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    a = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(compound.mms(c, a, b, neg=neg)),
        np.asarray(ref.mma_add_ref(c, a, b, neg=neg)),
        **TOL,
    )


# ---------------------------------------------------------------------------
# hypothesis sweeps (shapes / values) — L1 robustness
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=6), seed=st.integers(0, 2**31 - 1))
def test_cn_update_hypothesis_sweep(n, seed):
    rng = np.random.default_rng(seed)
    blkset, (vx, vy, a, mx, my) = cn_inputs_blk(rng, n)
    vz_k, mz_k = compound.cn_update(*blkset)
    vz_c, mz_c = ref.cn_update_complex(
        jnp.array(vx), jnp.array(vy), jnp.array(a), jnp.array(mx), jnp.array(my)
    )
    scale = max(1.0, float(np.max(np.abs(np.asarray(vz_c)))))
    assert float(jnp.max(jnp.abs(ref.unblk(vz_k) - vz_c))) < 5e-4 * scale
    mscale = max(1.0, float(np.max(np.abs(np.asarray(mz_c)))))
    assert float(jnp.max(jnp.abs(ref.unvecblk(mz_k) - mz_c))) < 5e-4 * mscale


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([2, 4, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
    diag=st.floats(min_value=0.5, max_value=10.0),
)
def test_faddeev_hypothesis_sweep(m, seed, diag):
    rng = np.random.default_rng(seed)
    gm = rng.standard_normal((m, m)).astype(np.float32)
    g = jnp.array(gm @ gm.T + np.eye(m, dtype=np.float32) * diag)
    b = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    c = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    d = jnp.array(rng.standard_normal((m, m)), dtype=jnp.float32)
    out = np.asarray(faddeev.faddeev(g, b, c, d))
    expect = np.asarray(ref.schur_ref(g, b, c, d))
    scale = max(1.0, np.max(np.abs(expect)))
    assert np.max(np.abs(out - expect)) < 1e-3 * scale
