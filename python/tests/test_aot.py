"""AOT pipeline smoke tests: HLO text artifacts parse and carry the right
signatures for the Rust loader."""

import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


def test_to_hlo_text_produces_entry():
    import jax

    lowered = jax.jit(model.cn_update).lower(*model.cn_example_args(2))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4,4]" in text  # n=2 -> block 4x4


def test_lower_all_covers_all_artifacts():
    names = [name for name, *_ in aot.lower_all(2, 4, 4)]
    assert names == ["cn_update", "cn_update_batched", "rls_chain"]


def test_aot_main_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as tmp:
        proc = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                tmp,
                "--n",
                "2",
                "--batch",
                "2",
                "--sections",
                "3",
            ],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert proc.returncode == 0, proc.stderr
        files = set(os.listdir(tmp))
        assert {
            "cn_update.hlo.txt",
            "cn_update_batched.hlo.txt",
            "rls_chain.hlo.txt",
            "manifest.txt",
        } <= files
        manifest = open(os.path.join(tmp, "manifest.txt")).read()
        assert "cn_update inputs=f32[4x4],f32[4x4],f32[4x4],f32[4],f32[4] outputs=2" in manifest
        assert manifest.startswith("n=2 batch=2 sections=3")
        hlo = open(os.path.join(tmp, "rls_chain.hlo.txt")).read()
        assert "ENTRY" in hlo


@pytest.mark.parametrize("n", [2, 4])
def test_hlo_text_is_stable_under_relowering(n):
    """Two lowerings of the same fn produce identical signatures (cache safety)."""
    import jax

    t1 = aot.to_hlo_text(jax.jit(model.cn_update).lower(*model.cn_example_args(n)))
    t2 = aot.to_hlo_text(jax.jit(model.cn_update).lower(*model.cn_example_args(n)))
    sig1 = [l for l in t1.splitlines() if "ENTRY" in l]
    sig2 = [l for l in t2.splitlines() if "ENTRY" in l]
    assert sig1 == sig2
