"""L2: the GMP compute graph in JAX, calling the Pallas kernels.

Three exported entry points, each AOT-lowered by ``aot.py`` into an HLO
text artifact the Rust runtime loads through PJRT:

* ``cn_update``          — one compound-node message update (Table II's op)
* ``cn_update_batched``  — B independent CN updates (the coordinator's
                           batched-offload path)
* ``rls_chain``          — the full RLS channel-estimation recursion of
                           Fig. 6 as a ``lax.scan`` over sections, state
                           (V, m) threaded through the scan carry exactly
                           like the FGP threads it through the message
                           memory

Everything is float32 real-block form (see kernels.ref).  Python never
runs at request time: these functions exist to be lowered once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import compound
from .kernels import ref as kref


def cn_update(vx, vy, a, mx, my):
    """One compound-node update (V_Z, m_Z) via the fused Pallas kernel."""
    return compound.cn_update(vx, vy, a, mx, my)


def cn_update_batched(vx, vy, a, mx, my):
    """Batched compound-node updates via the gridded Pallas kernel."""
    return compound.cn_update_batched(vx, vy, a, mx, my)


def rls_chain(v0, m0, a_seq, y_seq, sigma2):
    """RLS channel estimation over S sections (paper Fig. 6 / Listing 1).

    Args:
      v0:     (2n, 2n) prior covariance (block-real)
      m0:     (2n,)    prior mean
      a_seq:  (S, 2n, 2n) block-embedded regressor per section
      y_seq:  (S, 2n)  observation message per section
      sigma2: ()       observation noise variance

    Returns (v_seq, m_seq): the posterior after every section — the same
    trace the FGP leaves in its message memory after running the compiled
    Listing-2 program with ``loop``.
    """
    n2 = v0.shape[0]
    vy = jnp.eye(n2, dtype=jnp.float32) * sigma2

    def step(carry, sec):
        v, m = carry
        a, y = sec
        vz, mz = compound.cn_update(v, vy, a, m, y)
        return (vz, mz), (vz, mz)

    (_, _), (v_seq, m_seq) = lax.scan(step, (v0, m0), (a_seq, y_seq))
    return v_seq, m_seq


def rls_chain_ref(v0, m0, a_seq, y_seq, sigma2):
    """Pure-jnp twin of ``rls_chain`` (no Pallas) for A/B testing the AOT path."""
    n2 = v0.shape[0]
    vy = jnp.eye(n2, dtype=jnp.float32) * sigma2

    def step(carry, sec):
        v, m = carry
        a, y = sec
        vz, mz = kref.cn_update_blk_ref(v, vy, a, m, y)
        return (vz, mz), (vz, mz)

    (_, _), (v_seq, m_seq) = lax.scan(step, (v0, m0), (a_seq, y_seq))
    return v_seq, m_seq


def kalman_smoother_pass(v0, m0, a_seq, c_seq, q, r, y_seq):
    """Forward Kalman filtering pass expressed as alternating GMP nodes.

    Each time step is: multiplier node (state transition A), additive node
    (process noise Q), then a compound node (observation C with noise R).
    Used by tests to show the node algebra composes into a textbook filter;
    not part of the AOT artifact set (the Rust golden model covers it).
    """
    def step(carry, inp):
        v, m = carry
        a, c, y = inp
        # multiplier node: X' = A X
        v_pred = a @ v @ a.T + q
        m_pred = a @ m
        # compound (observation) node
        vz, mz = kref.cn_update_blk_ref(v_pred, r, c, m_pred, y)
        return (vz, mz), (vz, mz)

    (_, _), out = lax.scan(step, (v0, m0), (a_seq, c_seq, y_seq))
    return out


# ---------------------------------------------------------------------------
# Example-argument builders used by aot.py (shapes must be static for AOT)
# ---------------------------------------------------------------------------

def cn_example_args(n: int):
    """ShapeDtypeStructs for a single CN update with n x n complex state."""
    m = 2 * n
    mat = jax.ShapeDtypeStruct((m, m), jnp.float32)
    vec = jax.ShapeDtypeStruct((m,), jnp.float32)
    return (mat, mat, mat, vec, vec)


def cn_batched_example_args(n: int, batch: int):
    m = 2 * n
    mat = jax.ShapeDtypeStruct((batch, m, m), jnp.float32)
    vec = jax.ShapeDtypeStruct((batch, m), jnp.float32)
    return (mat, mat, mat, vec, vec)


def rls_example_args(n: int, sections: int):
    m = 2 * n
    return (
        jax.ShapeDtypeStruct((m, m), jnp.float32),           # v0
        jax.ShapeDtypeStruct((m,), jnp.float32),             # m0
        jax.ShapeDtypeStruct((sections, m, m), jnp.float32),  # a_seq
        jax.ShapeDtypeStruct((sections, m), jnp.float32),     # y_seq
        jax.ShapeDtypeStruct((), jnp.float32),               # sigma2
    )
