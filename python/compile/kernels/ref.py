"""Pure-jnp correctness oracles for the FGP compute kernels.

Everything the FGP's systolic array computes — the three operation types of
paper §II (matrix multiply, multiply-accumulate, Faddeev Schur complement)
and the full compound-node (CN) message update of Fig. 2 — is written here
in straightforward jax.numpy so the Pallas kernels (and, transitively, the
Rust golden model and the cycle-accurate simulator) have a single numeric
reference.

Complex representation
----------------------
The FGP hardware carries complex numbers on real multipliers (4 real
multiplies per complex multiply, paper Fig. 3).  We mirror that by working
in the *real block embedding*:

    M (n x n complex)  <->  blk(M) = [[Re M, -Im M], [Im M, Re M]]   (2n x 2n real)

which is an algebra isomorphism: blk(AB) = blk(A) blk(B),
blk(A + B) = blk(A) + blk(B), blk(A^H) = blk(A)^T and
blk(A^{-1}) = blk(A)^{-1}.  Complex vectors map to stacked [Re; Im]
(2n real) with blk(M) @ vec(x) = vec(M x).  All kernels operate on the
block form; pack/unpack helpers live here.
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Complex <-> real block embedding
# ---------------------------------------------------------------------------


def blk(m: jnp.ndarray) -> jnp.ndarray:
    """Embed a complex (n, n) matrix as its (2n, 2n) real block form."""
    re, im = jnp.real(m), jnp.imag(m)
    top = jnp.concatenate([re, -im], axis=-1)
    bot = jnp.concatenate([im, re], axis=-1)
    return jnp.concatenate([top, bot], axis=-2).astype(jnp.float32)


def unblk(b: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`blk` (takes the left block column as Re / Im)."""
    n = b.shape[-1] // 2
    return b[..., :n, :n] + 1j * b[..., n:, :n]


def vecblk(v: jnp.ndarray) -> jnp.ndarray:
    """Embed a complex (n,) vector as stacked [Re; Im] (2n,) reals."""
    return jnp.concatenate([jnp.real(v), jnp.imag(v)], axis=-1).astype(jnp.float32)


def unvecblk(b: jnp.ndarray) -> jnp.ndarray:
    n = b.shape[-1] // 2
    return b[..., :n] + 1j * b[..., n:]


# ---------------------------------------------------------------------------
# The three FGP operation types (paper Section II), real block domain
# ---------------------------------------------------------------------------


def mm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """`mma`: plain matrix-matrix multiply (e.g. V_X A^H)."""
    return a @ b


def mma_add_ref(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, neg: bool = True) -> jnp.ndarray:
    """`mms`: multiply with addition/subtraction, C -/+ A B (e.g. V_Y - A(V_X A^H))."""
    prod = a @ b
    return c - prod if neg else c + prod


def schur_ref(g: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Faddeev result D - C G^{-1} B (paper's Schur-complement operation).

    Block elimination of [[G, B], [C, D]] leaves D - C G^{-1} B in the
    lower-right quadrant.  With C = V_X A^H, B = A V_X, D = V_X this yields
    the compound-node covariance V_Z = V_X - V_X A^H G^{-1} A V_X.
    """
    return d - c @ jnp.linalg.solve(g, b)


# ---------------------------------------------------------------------------
# Compound-node message update (Fig. 2 + ref [3] eqns), complex domain
# ---------------------------------------------------------------------------


def cn_update_complex(vx, vy, a, mx, my):
    """Reference compound-node update in plain complex arithmetic.

    Node: X --[A]--> (+) <-- Y ; outgoing message Z (Kalman measurement
    update form):

        G   = V_Y + A V_X A^H
        V_Z = V_X - V_X A^H G^{-1} A V_X
        m_Z = m_X + V_X A^H G^{-1} (m_Y - A m_X)
    """
    ah = jnp.conj(a).T
    t1 = vx @ ah                          # V_X A^H       (mma)
    g = vy + a @ t1                       # G             (mms, add)
    gain = jnp.linalg.solve(g.T, t1.T).T  # V_X A^H G^{-1}
    vz = vx - gain @ (a @ vx)             # Schur complement (fad)
    mz = mx + gain @ (my - a @ mx)
    return vz, mz


def cn_update_blk_ref(vx, vy, a, mx, my):
    """Compound-node update in the real block domain (what the kernel does).

    All matrix args are (2n, 2n) block-form, vectors are (2n,) stacked
    [Re; Im].  Hermitian transpose of the complex matrix == plain transpose
    of the block form.
    """
    t1 = vx @ a.T                         # blk(V_X A^H)
    avx = a @ vx                          # blk(A V_X)
    g = vy + a @ t1                       # blk(G)
    gain = jnp.linalg.solve(g.T, t1.T).T
    vz = vx - gain @ avx
    mz = mx + gain @ (my - a @ mx)
    return vz, mz


def faddeev_extended_ref(g, b, c, d, y, x):
    """Extended Faddeev: eliminate [[G, B | y], [C, D | x]] -> D - C G^{-1} B, x - C G^{-1} y.

    This folds the mean update into the same elimination the covariance
    uses — mirroring how the FGP streams the mean vector through the array
    as an extra column.
    """
    ginv_b = jnp.linalg.solve(g, b)
    ginv_y = jnp.linalg.solve(g, y[:, None])[:, 0]
    return d - c @ ginv_b, x - c @ ginv_y


# ---------------------------------------------------------------------------
# Simple-node update rules (paper Fig. 1) — used by L2 model tests
# ---------------------------------------------------------------------------


def equality_node_ref(wx, wxm, wy, wym):
    """Equality node in weight form: W_Z = W_X + W_Y, (Wm)_Z = (Wm)_X + (Wm)_Y."""
    return wx + wy, wxm + wym


def add_node_ref(vx, mx, vy, my):
    """Additive node in covariance form: V_Z = V_X + V_Y, m_Z = m_X + m_Y."""
    return vx + vy, mx + my


def matmul_node_ref(vx, mx, a):
    """Multiplier node Y = A X: V_Y = A V_X A^H (block: A V A^T), m_Y = A m_X."""
    return a @ vx @ a.T, a @ mx


# ---------------------------------------------------------------------------
# RLS / LMMSE channel estimation chain (paper Section IV, Fig. 6)
# ---------------------------------------------------------------------------


def rls_chain_ref(v0, m0, a_seq, y_seq, sigma2):
    """Sequential reference for the RLS channel-estimation factor graph.

    One section per received symbol: the state (channel-estimate posterior)
    passes through a compound node whose A is the (block-embedded) regressor
    and whose V_Y is the observation-noise covariance sigma2 * I.

    Args (all real block form):
      v0:    (2n, 2n) prior covariance
      m0:    (2n,)    prior mean
      a_seq: (S, 2n, 2n) block-embedded regressor matrices
      y_seq: (S, 2n) observation messages
      sigma2: scalar noise variance (> 0)
    """
    s = a_seq.shape[0]
    n2 = v0.shape[0]
    vy = jnp.eye(n2, dtype=jnp.float32) * sigma2
    v, m = v0, m0
    out_v, out_m = [], []
    for i in range(s):
        v, m = cn_update_blk_ref(v, vy, a_seq[i], m, y_seq[i])
        out_v.append(v)
        out_m.append(m)
    return jnp.stack(out_v), jnp.stack(out_m)
