"""Fused Pallas kernel for the compound-node (CN) message update (Fig. 2).

This is the FGP's hottest operation — the paper's Table II benchmarks
exactly this update.  The hardware chains three systolic passes without
spilling intermediates to memory (results persist in the PEmult StateReg,
paper §II); the kernel mirrors that by fusing all three stages so nothing
round-trips through HBM:

    stage 1 (mma):  T1 = V_X A^H            — StateReg accumulate
    stage 2 (mms):  G  = V_Y + A T1         — StateReg shift + add
    stage 3 (fad):  V_Z = V_X - T1 G^{-1} (A V_X)   — Faddeev elimination
                    m_Z = m_X + T1 G^{-1} (m_Y - A m_X)

All operands are in the real block embedding (see kernels.ref): complex
n x n matrices become real 2n x 2n, Hermitian transpose becomes plain
transpose, and a complex multiply costs 4 real multiplies — the same
factor-4 the PEmult pays on its single real multiplier.

Batched variant: a 1-D grid over the batch with BlockSpec picking one
(2n, 2n) tile per grid step — the HBM->VMEM schedule that the paper's
Select/Mask units implement with memory ports.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .faddeev import INTERPRET, eliminate


def _cn_kernel(vx_ref, vy_ref, a_ref, mx_ref, my_ref, vz_ref, mz_ref, *, m: int):
    vx = vx_ref[...]
    vy = vy_ref[...]
    a = a_ref[...]
    mx = mx_ref[...]
    my = my_ref[...]

    t1 = vx @ a.T                # mma: V_X A^H  (block transpose == Hermitian)
    avx = a @ vx                 # mma: A V_X
    g = vy + a @ t1              # mms: V_Y + A (V_X A^H)
    y = a @ mx - my              # negated innovation (sign folds the mean
                                 # update into the same elimination as V_Z)

    # fad: eliminate [[G, A V_X, y], [T1, V_X, mx]]; block elimination
    # leaves D - C G^{-1} B in the bottom-right, i.e.
    #   V_Z = V_X - T1 G^{-1} A V_X,  m_Z = m_X - T1 G^{-1} y
    #       = m_X + T1 G^{-1} (m_Y - A m_X).
    top = jnp.concatenate([g, avx, y[:, None]], axis=1)
    bot = jnp.concatenate([t1, vx, mx[:, None]], axis=1)
    w = eliminate(jnp.concatenate([top, bot], axis=0), m)

    vz_ref[...] = w[m:, m:2 * m]
    mz_ref[...] = w[m:, 2 * m]


def cn_update(vx, vy, a, mx, my):
    """Single compound-node update; all args block-real ((2n,2n) / (2n,))."""
    m = vx.shape[-1]
    return pl.pallas_call(
        functools.partial(_cn_kernel, m=m),
        out_shape=(
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(vx, vy, a, mx, my)


def _cn_kernel_batched(vx_ref, vy_ref, a_ref, mx_ref, my_ref, vz_ref, mz_ref, *, m: int):
    """Grid step: one batch element, tiles already sliced by BlockSpec."""
    vx = vx_ref[0]
    vy = vy_ref[0]
    a = a_ref[0]
    mx = mx_ref[0]
    my = my_ref[0]

    t1 = vx @ a.T
    avx = a @ vx
    g = vy + a @ t1
    y = a @ mx - my

    top = jnp.concatenate([g, avx, y[:, None]], axis=1)
    bot = jnp.concatenate([t1, vx, mx[:, None]], axis=1)
    w = eliminate(jnp.concatenate([top, bot], axis=0), m)

    vz_ref[0] = w[m:, m:2 * m]
    mz_ref[0] = w[m:, 2 * m]


def cn_update_batched(vx, vy, a, mx, my):
    """Batched CN update: (B, 2n, 2n) x 3 matrices + (B, 2n) x 2 vectors.

    One grid step per request; each step's working set (a few KB at n=4)
    lives in VMEM, so the grid is the HBM->VMEM pipeline.
    """
    b, m, _ = vx.shape
    mat_spec = pl.BlockSpec((1, m, m), lambda i: (i, 0, 0))
    vec_spec = pl.BlockSpec((1, m), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cn_kernel_batched, m=m),
        grid=(b,),
        in_specs=[mat_spec, mat_spec, mat_spec, vec_spec, vec_spec],
        out_specs=(mat_spec, vec_spec),
        out_shape=(
            jax.ShapeDtypeStruct((b, m, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m), jnp.float32),
        ),
        interpret=INTERPRET,
    )(vx, vy, a, mx, my)


def _mm_kernel(a_ref, b_ref, o_ref):
    """`mma` in isolation: plain tile matmul (tests + unit benches)."""
    o_ref[...] = a_ref[...] @ b_ref[...]


def mm(a, b):
    m = a.shape[0]
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, b.shape[1]), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


def _mms_kernel(c_ref, a_ref, b_ref, o_ref, *, neg: bool):
    """`mms` in isolation: C -/+ A B with the product accumulated in-array."""
    prod = a_ref[...] @ b_ref[...]
    o_ref[...] = c_ref[...] - prod if neg else c_ref[...] + prod


def mms(c, a, b, neg: bool = True):
    return pl.pallas_call(
        functools.partial(_mms_kernel, neg=neg),
        out_shape=jax.ShapeDtypeStruct(c.shape, jnp.float32),
        interpret=INTERPRET,
    )(c, a, b)
