"""Pallas kernel for the Faddeev algorithm (paper §II, third operation type).

The FGP computes Schur complements ``D + C G^{-1} B`` by streaming the
doubled matrix ``[[G, B], [C, D]]`` through the systolic array: the
triangular PEborder extension triangularizes the top block rows (pivot
division on the border PE, row updates on the PEmult grid) and Gaussian
elimination of the bottom block rows leaves the Schur complement in the
lower-right quadrant.  No explicit inverse is ever formed — that is the
paper's key efficiency argument versus the DSP.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the whole doubled working
set for n=4 complex (block-real 16x17 floats) trivially fits VMEM, so the
kernel materializes it as a kernel-local value and performs the
elimination with a ``fori_loop`` whose body does one pivot step — a
vectorized rank-1 update, which is exactly the wavefront the systolic
array executes in hardware.

The elimination runs WITHOUT pivoting: every G the compound node produces
is (block-real symmetric) positive definite (G = V_Y + A V_X A^H with PSD
inputs), so the pivots are bounded away from zero.  The cycle-accurate
Rust simulator implements the hardware's row-swap pivoting (PEmult swap
mode); numerically both agree on PD inputs.

All kernels run ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def eliminate(w: jnp.ndarray, m: int) -> jnp.ndarray:
    """Run m pivot steps of Faddeev elimination on w ((2m, cols) working set).

    Step k scales the pivot row by 1/w[k,k] (the PEborder division) and
    subtracts w[i,k] * pivot_row from every row i > k (the PEmult
    multiply-subtract wavefront).  Shared by all kernels below.
    """
    rows = w.shape[0]
    row_idx = lax.broadcasted_iota(jnp.int32, (rows, 1), 0)

    def step(k, w):
        piv = w[k, k]
        pivot_row = w[k, :] / piv                       # PEborder: divide
        factors = w[:, k][:, None]                      # column of multipliers
        mask = (row_idx > k).astype(w.dtype)            # only rows below pivot
        return w - mask * factors * pivot_row[None, :]  # PEmult: mult-subtract

    return lax.fori_loop(0, m, step, w)


def _faddeev_kernel(g_ref, b_ref, c_ref, d_ref, out_ref, *, m: int):
    """out = D - C G^{-1} B via elimination of [[G, B], [C, D]]."""
    top = jnp.concatenate([g_ref[...], b_ref[...]], axis=1)
    bot = jnp.concatenate([c_ref[...], d_ref[...]], axis=1)
    w = eliminate(jnp.concatenate([top, bot], axis=0), m)
    out_ref[...] = w[m:, m:]


def faddeev(g: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Schur complement ``D - C G^{-1} B`` for (m, m) real blocks."""
    m = g.shape[-1]
    return pl.pallas_call(
        functools.partial(_faddeev_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=INTERPRET,
    )(g, b, c, d)


def _faddeev_ext_kernel(g_ref, b_ref, c_ref, d_ref, y_ref, x_ref,
                        vz_ref, mz_ref, *, m: int):
    """Extended Faddeev folding the mean column into the same elimination.

    Working-set layout (the extra column is the mean streamed through the
    array after the matrix columns, exactly as the FGP does):

        [[ G, B, y ],     eliminate     [[ *, *, * ],
         [ C, D, x ]]    ----------->    [ 0, D - C G^{-1} B, x - C G^{-1} y ]]
    """
    top = jnp.concatenate([g_ref[...], b_ref[...], y_ref[...][:, None]], axis=1)
    bot = jnp.concatenate([c_ref[...], d_ref[...], x_ref[...][:, None]], axis=1)
    w = eliminate(jnp.concatenate([top, bot], axis=0), m)
    vz_ref[...] = w[m:, m:2 * m]
    mz_ref[...] = w[m:, 2 * m]


def faddeev_extended(g, b, c, d, y, x):
    """(D - C G^{-1} B, x - C G^{-1} y) in one elimination pass."""
    m = g.shape[-1]
    return pl.pallas_call(
        functools.partial(_faddeev_ext_kernel, m=m),
        out_shape=(
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(g, b, c, d, y, x)
