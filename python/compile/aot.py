"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO text.

Run once at build time (``make artifacts``); the Rust runtime loads the
resulting ``artifacts/*.hlo.txt`` through the PJRT C API and Python never
appears on the request path again.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Alongside each artifact a ``manifest.txt`` records name, input shapes and
output arity so the Rust loader can validate its marshalling at startup.

Usage: ``python -m compile.aot --out ../artifacts [--n 4] [--batch 32] [--sections 64]``
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    dims = "x".join(str(d) for d in s.shape) if s.shape else "scalar"
    return f"f32[{dims}]"


def lower_all(n: int, batch: int, sections: int):
    """Yield (name, example_args, lowered) for every artifact we ship."""
    jobs = [
        ("cn_update", model.cn_update, model.cn_example_args(n), 2),
        (
            "cn_update_batched",
            model.cn_update_batched,
            model.cn_batched_example_args(n, batch),
            2,
        ),
        ("rls_chain", model.rls_chain, model.rls_example_args(n, sections), 2),
    ]
    for name, fn, args, n_out in jobs:
        lowered = jax.jit(fn).lower(*args)
        yield name, args, lowered, n_out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--n", type=int, default=4, help="complex state size n (paper: 4)")
    ap.add_argument("--batch", type=int, default=32, help="batched-CN batch size")
    ap.add_argument("--sections", type=int, default=64, help="RLS chain length")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_lines = [f"n={args.n} batch={args.batch} sections={args.sections}"]
    for name, ex_args, lowered, n_out in lower_all(args.n, args.batch, args.sections):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        sig = ",".join(_shape_str(a) for a in ex_args)
        manifest_lines.append(f"{name} inputs={sig} outputs={n_out}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
